"""Federated data partitioning.

* ``partition_iid`` — uniform random split.
* ``partition_noniid`` — the sort-and-shard method of Zhao et al. [1] /
  McMahan et al.: sort by label, cut into ``shards_per_client * n`` shards,
  deal each client ``shards_per_client`` shards → each client sees only a few
  classes.  This is the Non-IID generator referenced in paper §VII.D.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def partition_iid(n_items: int, n_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    order = rng.permutation(n_items)
    return [np.sort(chunk) for chunk in np.array_split(order, n_clients)]


def partition_noniid(labels: np.ndarray, n_clients: int,
                     shards_per_client: int = 2,
                     seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    order = np.argsort(labels, kind="stable")
    n_shards = n_clients * shards_per_client
    shards = np.array_split(order, n_shards)
    assignment = rng.permutation(n_shards)
    out = []
    for c in range(n_clients):
        mine = assignment[c * shards_per_client:(c + 1) * shards_per_client]
        out.append(np.sort(np.concatenate([shards[s] for s in mine])))
    return out


def partition_by_topic(topics: np.ndarray, n_clients: int,
                       topics_per_client: int = 2,
                       seed: int = 0) -> List[np.ndarray]:
    """Non-IID federated token streams: each client's corpus covers only a
    few Markov topics.

    The LM analogue of the label sort-and-shard split: documents are sorted
    by their latent topic id (data.synthetic.markov_topic_tokens) and each
    client is dealt ``topics_per_client`` contiguous shards, so its local
    next-token statistics come from a small subset of the topic mixture —
    the token-stream counterpart of "each client sees only a few classes".
    """
    return partition_noniid(topics, n_clients,
                            shards_per_client=topics_per_client, seed=seed)


def label_distribution(labels: np.ndarray, parts: List[np.ndarray],
                       num_classes: int) -> np.ndarray:
    """(clients, classes) histogram — used to verify Non-IID skew in tests."""
    return np.stack([np.bincount(labels[p], minlength=num_classes)
                     for p in parts])
