"""Federated data partitioning.

* ``partition_iid`` — uniform random split.
* ``partition_noniid`` — the sort-and-shard method of Zhao et al. [1] /
  McMahan et al.: sort by label, cut into ``shards_per_client * n`` shards,
  deal each client ``shards_per_client`` shards → each client sees only a few
  classes.  This is the Non-IID generator referenced in paper §VII.D.

Every partition has a ``*_lazy`` twin that is index-for-index equal but
stores O(1) shared state instead of ``n_clients`` index arrays — the
population-scale engines only materialize the clients actually drawn into
a cohort (or popped off the async event heap).
"""
from __future__ import annotations

from typing import List

import numpy as np


def partition_iid(n_items: int, n_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    order = rng.permutation(n_items)
    return [np.sort(chunk) for chunk in np.array_split(order, n_clients)]


def partition_noniid(labels: np.ndarray, n_clients: int,
                     shards_per_client: int = 2,
                     seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    order = np.argsort(labels, kind="stable")
    n_shards = n_clients * shards_per_client
    shards = np.array_split(order, n_shards)
    assignment = rng.permutation(n_shards)
    out = []
    for c in range(n_clients):
        mine = assignment[c * shards_per_client:(c + 1) * shards_per_client]
        out.append(np.sort(np.concatenate([shards[s] for s in mine])))
    return out


def partition_by_topic(topics: np.ndarray, n_clients: int,
                       topics_per_client: int = 2,
                       seed: int = 0) -> List[np.ndarray]:
    """Non-IID federated token streams: each client's corpus covers only a
    few Markov topics.

    The LM analogue of the label sort-and-shard split: documents are sorted
    by their latent topic id (data.synthetic.markov_topic_tokens) and each
    client is dealt ``topics_per_client`` contiguous shards, so its local
    next-token statistics come from a small subset of the topic mixture —
    the token-stream counterpart of "each client sees only a few classes".
    """
    return partition_noniid(topics, n_clients,
                            shards_per_client=topics_per_client, seed=seed)


def _split_bounds(n: int, k: int) -> np.ndarray:
    """Chunk boundaries of ``np.array_split(range(n), k)``: the first
    ``n % k`` chunks get one extra item.  BOTH lazy partitions derive their
    slices from this, so eager/lazy index-equality rests on one formula."""
    sizes = [n // k + (1 if i < n % k else 0) for i in range(k)]
    return np.concatenate([[0], np.cumsum(sizes)])


class _LazyView:
    """One client's sorted index slice, materialized on demand.

    Behaves like an ndarray wherever the adapters need one (``len`` for the
    replacement decision, ``np.asarray`` for the actual draw) without
    holding a per-client copy.
    """

    __slots__ = ("_perm", "_lo", "_hi")

    def __init__(self, perm: np.ndarray, lo: int, hi: int):
        self._perm, self._lo, self._hi = perm, lo, hi

    def __len__(self) -> int:
        return self._hi - self._lo

    def __array__(self, dtype=None, copy=None):
        out = np.sort(self._perm[self._lo:self._hi])
        return out.astype(dtype) if dtype is not None else out


class LazyParts:
    """List-like IID partition over ``n_clients`` that stores ONE shared
    permutation instead of ``n_clients`` index arrays.

    Produces exactly the same per-client indices as :func:`partition_iid`
    for the same seed (same permutation, same ``array_split`` boundaries),
    so population-scale engines can swap it in without changing draws.
    """

    def __init__(self, perm: np.ndarray, n_clients: int):
        self._perm = perm
        self._bounds = _split_bounds(len(perm), n_clients)

    def __len__(self) -> int:
        return len(self._bounds) - 1

    def __getitem__(self, i: int) -> _LazyView:
        if i < 0:
            i += len(self)
        return _LazyView(self._perm, int(self._bounds[i]),
                         int(self._bounds[i + 1]))


def partition_iid_lazy(n_items: int, n_clients: int,
                       seed: int = 0) -> LazyParts:
    """IID split that never materializes per-client arrays (N=4096-scale
    populations); index-for-index equal to :func:`partition_iid`."""
    rng = np.random.default_rng(seed)
    return LazyParts(rng.permutation(n_items), n_clients)


class _LazyShardView:
    """One client's dealt shards, materialized (sorted + concatenated) on
    demand — the non-IID counterpart of :class:`_LazyView`."""

    __slots__ = ("_order", "_bounds", "_shards")

    def __init__(self, order: np.ndarray, bounds: np.ndarray,
                 shards: np.ndarray):
        self._order, self._bounds, self._shards = order, bounds, shards

    def __len__(self) -> int:
        return int(sum(self._bounds[s + 1] - self._bounds[s]
                       for s in self._shards))

    def __array__(self, dtype=None, copy=None):
        out = np.sort(np.concatenate(
            [self._order[self._bounds[s]:self._bounds[s + 1]]
             for s in self._shards]))
        return out.astype(dtype) if dtype is not None else out


class LazyShardParts:
    """List-like sort-and-shard partition that stores ONE label ordering +
    ONE shard assignment instead of ``n_clients`` index arrays.

    Index-for-index equal to :func:`partition_noniid` for the same seed:
    the same stable argsort, the same ``array_split`` shard boundaries, the
    same permuted deal — only the per-client concatenation is deferred to
    the clients actually sampled into a cohort.
    """

    def __init__(self, order: np.ndarray, n_clients: int,
                 shards_per_client: int, assignment: np.ndarray):
        self._order = order
        self._spc = shards_per_client
        self._assignment = assignment
        self._bounds = _split_bounds(len(order),
                                     n_clients * shards_per_client)
        self._n_clients = n_clients

    def __len__(self) -> int:
        return self._n_clients

    def __getitem__(self, c: int) -> _LazyShardView:
        if c < 0:
            c += len(self)
        mine = self._assignment[c * self._spc:(c + 1) * self._spc]
        return _LazyShardView(self._order, self._bounds, mine)


def partition_noniid_lazy(labels: np.ndarray, n_clients: int,
                          shards_per_client: int = 2,
                          seed: int = 0) -> LazyShardParts:
    """Sort-and-shard non-IID split without materializing per-client index
    arrays; index-for-index equal to :func:`partition_noniid`."""
    rng = np.random.default_rng(seed)
    order = np.argsort(labels, kind="stable")
    assignment = rng.permutation(n_clients * shards_per_client)
    return LazyShardParts(order, n_clients, shards_per_client, assignment)


def partition_by_topic_lazy(topics: np.ndarray, n_clients: int,
                            topics_per_client: int = 2,
                            seed: int = 0) -> LazyShardParts:
    """Lazy variant of :func:`partition_by_topic` (same deal, deferred
    materialization) for population-scale federated LM streams."""
    return partition_noniid_lazy(topics, n_clients,
                                 shards_per_client=topics_per_client,
                                 seed=seed)


def label_distribution(labels: np.ndarray, parts: List[np.ndarray],
                       num_classes: int) -> np.ndarray:
    """(clients, classes) histogram — used to verify Non-IID skew in tests."""
    return np.stack([np.bincount(labels[p], minlength=num_classes)
                     for p in parts])
