from repro.data import federated, synthetic

__all__ = ["synthetic", "federated"]
