"""Synthetic datasets (offline container — no MNIST/CIFAR downloads).

* ``class_gaussian_images`` — MNIST/CIFAR-shaped classification data: each
  class has a random low-frequency template; samples = template + noise.
  Linear-separable enough to converge in tens of steps, hard enough that
  convergence ORDER between FL schemes is informative (the reproduction
  target — DESIGN.md §7.3).
* ``markov_tokens`` — LM pretraining streams from a random per-document
  Markov chain over the vocab: next-token entropy is well below uniform, so
  CE falls measurably within a few hundred steps of the ~100M-param example.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def class_gaussian_images(num: int, image_size: int, channels: int,
                          num_classes: int, seed: int = 0,
                          noise: float = 0.7,
                          template_seed: int = 1234
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (images (N,H,W,C) float32, labels (N,) int32).

    ``template_seed`` fixes the class templates independently of the sample
    ``seed`` so train/test splits drawn with different seeds share the same
    class structure.
    """
    trng = np.random.default_rng(template_seed)
    rng = np.random.default_rng(seed)
    # low-frequency class templates (smooth random fields)
    low = max(2, image_size // 4)
    templates = trng.normal(size=(num_classes, low, low, channels))
    reps = int(np.ceil(image_size / low))
    templates = np.kron(templates, np.ones((1, reps, reps, 1)))[
        :, :image_size, :image_size, :]
    labels = rng.integers(0, num_classes, size=num).astype(np.int32)
    images = templates[labels] + noise * rng.normal(
        size=(num, image_size, image_size, channels))
    return images.astype(np.float32), labels


def markov_tokens(num_seqs: int, seq_len: int, vocab: int, seed: int = 0,
                  branching: int = 8) -> np.ndarray:
    """(N, S) int32 sequences from a sparse random Markov chain.

    Each token has ``branching`` plausible successors -> ~log2(branching)
    bits/token achievable vs log2(vocab) at random.
    """
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, branching))
    out = np.empty((num_seqs, seq_len), np.int32)
    state = rng.integers(0, vocab, size=num_seqs)
    for t in range(seq_len):
        out[:, t] = state
        choice = rng.integers(0, branching, size=num_seqs)
        state = succ[state, choice]
    return out


def markov_topic_tokens(num_seqs: int, seq_len: int, vocab: int,
                        n_topics: int = 8, seed: int = 0,
                        branching: int = 8, table_seed: int = 1234
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """(N, S) int32 sequences + (N,) int32 latent topic per document.

    Each topic is its own sparse random Markov chain (independent successor
    table), so documents of different topics have disjoint transition
    statistics.  The topic id plays the role of the class label in the
    federated Non-IID split: dealing whole topics to clients
    (data.federated.partition_by_topic) skews per-client token statistics
    the same way label sort-and-shard skews per-client class histograms.

    ``table_seed`` fixes the per-topic transition tables independently of
    the sample ``seed`` so train/test streams drawn with different seeds
    share the same underlying language (mirrors ``template_seed`` above).
    """
    trng = np.random.default_rng(table_seed)
    rng = np.random.default_rng(seed)
    succ = trng.integers(0, vocab, size=(n_topics, vocab, branching))
    topics = rng.integers(0, n_topics, size=num_seqs).astype(np.int32)
    out = np.empty((num_seqs, seq_len), np.int32)
    state = rng.integers(0, vocab, size=num_seqs)
    for t in range(seq_len):
        out[:, t] = state
        choice = rng.integers(0, branching, size=num_seqs)
        state = succ[topics, state, choice]
    return out, topics


def batches(arrays, batch_size: int, seed: int = 0, epochs: int = 10 ** 9):
    """Shuffled minibatch iterator over aligned arrays."""
    n = len(arrays[0])
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i:i + batch_size]
            yield tuple(a[idx] for a in arrays)
