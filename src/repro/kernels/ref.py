"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_matmul_ref(x, w, block_alive, block_n: int):
    """y = x @ (w * column-block mask)."""
    n = w.shape[1]
    mask = jnp.repeat(block_alive.astype(w.dtype), block_n)[:n]
    return x @ (w * mask[None, :])


def flash_attention_ref(q, k, v, causal: bool = True):
    """Dense softmax attention. q,k,v: (B, H, S, hd)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqk,bhsk->bhqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bhsk->bhqk", p, v.astype(jnp.float32)).astype(
        q.dtype)


def ssd_diag_ref(cr, br, cum, dtx):
    """Intra-chunk SSD diagonal term (the einsum form from models/ssm.py)."""
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (b,nc,L,L,nh)
    L = cr.shape[2]
    tril = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(tril[None, None, :, :, None],
                      jnp.exp(seg.astype(jnp.float32)), 0.0)
    cb = jnp.einsum("bnli,bnmi->bnlm", cr.astype(jnp.float32),
                    br.astype(jnp.float32))
    return jnp.einsum("bnlm,bnlmh,bnmhp->bnlhp", cb, decay,
                      dtx.astype(jnp.float32)).astype(dtx.dtype)
