"""Block-sparse masked matmul — the TPU-native soft-training hot spot.

Paper semantics: a straggler trains only the selected hidden units, i.e.
``y = x @ (W * unit_mask[None, :])``.  A 0/1 mask saves nothing on the MXU,
so the TPU adaptation makes the sparsity STRUCTURAL: Helios selection is
block-aligned (units chosen in groups of ``block_n``, a beyond-paper
optimization recorded in DESIGN.md §2), and this kernel SKIPS whole masked
column blocks: the (bm, bn) output tile for a dead block is written as zeros
without loading W or running the MXU — compute and HBM traffic both drop by
the volume fraction P, which is exactly the paper's edge-device speedup
mechanism re-expressed for the MXU.

Grid: (M/bm, N/bn, K/bk), K innermost for accumulation.  ``block_alive`` is
a precomputed (N/bn,) flag vector (mask.reshape(-1, bn).any(1)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(alive_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; K-blocks arrive sequentially (innermost)."""
    k_idx = pl.program_id(2)
    alive = alive_ref[0] != 0

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(alive)
    def _mac():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "block_k",
                                    "interpret"))
def masked_matmul(x: jax.Array, w: jax.Array, block_alive: jax.Array,
                  *, block_m: int = 128, block_n: int = 128,
                  block_k: int = 128, interpret: bool = False) -> jax.Array:
    """y = x @ w with dead column-blocks skipped.

    x: (M, K); w: (K, N); block_alive: (N // block_n,) int32/bool.
    Masked-out columns of the result are ZERO (matching W*mask semantics
    when the mask is block-aligned).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, \
        (x.shape, w.shape, block_m, block_n, block_k)
    n_k = k // block_k

    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, kk: (j,)),            # alive flag
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(block_alive.astype(jnp.int32), x, w)
