"""Block-sparse masked matmul — the TPU-native soft-training hot spot.

Paper semantics: a straggler trains only the selected hidden units, i.e.
``y = x @ (W * unit_mask[None, :])``.  A 0/1 mask saves nothing on the MXU,
so the TPU adaptation makes the sparsity STRUCTURAL: Helios selection is
block-aligned (units chosen in groups of ``block_n``, a beyond-paper
optimization recorded in DESIGN.md §2), and this kernel SKIPS whole masked
column blocks: the (bm, bn) output tile for a dead block is written as zeros
without loading W or running the MXU — compute and HBM traffic both drop by
the volume fraction P, which is exactly the paper's edge-device speedup
mechanism re-expressed for the MXU.

Grid: (M/bm, N/bn, K/bk), K innermost for accumulation.  ``block_alive`` is
a precomputed flag vector (mask.reshape(-1, bn).any(1)).

One kernel body serves both directions of the soft-training VJP — only the
grid axis the alive flag indexes differs:

* ``masked_matmul`` — flags index the OUTPUT-COLUMN (N) blocks: dead
  columns of y are written as zeros (the forward pass, and dw in the
  backward).
* ``masked_matmul_dk`` — flags index the CONTRACTION (K) blocks: dx =
  dy @ Wᵀ skipping K-blocks whose columns were masked out of the forward —
  exact whenever the skipped operand rows are zero, which the masked
  forward guarantees (dead columns of y, hence of dy·mask, are zero).
  Together the two make fwd AND bwd scale with the volume fraction P.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(alive_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; K-blocks arrive sequentially (innermost).
    ``alive_ref`` holds this grid point's flag — which axis it came from is
    decided by the BlockSpec index_map below."""
    k_idx = pl.program_id(2)
    alive = alive_ref[0] != 0

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(alive)
    def _mac():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _call(x, w, block_alive, alive_axis, block_m, block_n, block_k,
          interpret):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, \
        (x.shape, w.shape, block_m, block_n, block_k)
    n_k = k // block_k
    alive_spec = pl.BlockSpec((1,), (lambda i, j, kk: (j,)) if
                              alive_axis == "n" else (lambda i, j, kk: (kk,)))
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(m // block_m, n // block_n, n_k),
        in_specs=[
            alive_spec,
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(block_alive.astype(jnp.int32), x, w)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "block_k",
                                    "interpret"))
def masked_matmul(x: jax.Array, w: jax.Array, block_alive: jax.Array,
                  *, block_m: int = 128, block_n: int = 128,
                  block_k: int = 128, interpret: bool = False) -> jax.Array:
    """y = x @ w with dead column-blocks skipped.

    x: (M, K); w: (K, N); block_alive: (N // block_n,) int32/bool.
    Masked-out columns of the result are ZERO (matching W*mask semantics
    when the mask is block-aligned).
    """
    return _call(x, w, block_alive, "n", block_m, block_n, block_k,
                 interpret)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "block_k",
                                    "interpret"))
def masked_matmul_dk(x: jax.Array, w: jax.Array, block_alive: jax.Array,
                     *, block_m: int = 128, block_n: int = 128,
                     block_k: int = 128, interpret: bool = False) -> jax.Array:
    """y = x @ w with dead CONTRACTION (K) blocks skipped.

    x: (M, K); w: (K, N); block_alive: (K // block_k,) int32/bool.  Exact
    equality with the dense product requires the skipped blocks' operand
    entries to be zero (true for masked-gradient cotangents dy·mask and for
    masked hidden activations h·mask).
    """
    return _call(x, w, block_alive, "k", block_m, block_n, block_k,
                 interpret)
