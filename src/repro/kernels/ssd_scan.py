"""Mamba2 SSD intra-chunk kernel (pl.pallas_call + BlockSpec VMEM tiling).

Computes the FLOP-dominant diagonal-block term of the chunked SSD algorithm
for one (batch-chunk, head) tile entirely in VMEM:

    y[l, p] = sum_{m<=l} (C_l . B_m) * exp(cum_a[l] - cum_a[m]) * dtx[m, p]

(models/ssm.ssd_chunked computes the same quantity with materialized
(L, L, nh) decay tensors in HBM — the kernel keeps them in VMEM.)
Grid: (batch*chunks, heads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(c_ref, b_ref, cum_ref, dtx_ref, o_ref):
    c = c_ref[0].astype(jnp.float32)                       # (L, ds)
    b = b_ref[0].astype(jnp.float32)                       # (L, ds)
    cum = cum_ref[0, :, 0].astype(jnp.float32)             # (L,)
    dtx = dtx_ref[0, :, 0, :].astype(jnp.float32)          # (L, hd)

    L = c.shape[0]
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)   # (L, L)
    seg = cum[:, None] - cum[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    mi = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(li >= mi, jnp.exp(seg), 0.0)
    scores = cb * decay                                        # (L, L)
    o_ref[0, :, 0, :] = jnp.dot(
        scores, dtx, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_diag(cr: jax.Array, br: jax.Array, cum: jax.Array, dtx: jax.Array,
             *, interpret: bool = False) -> jax.Array:
    """Intra-chunk SSD.

    cr, br: (B, nc, L, ds); cum: (B, nc, L, nh); dtx: (B, nc, L, nh, hd).
    Returns y_diag: (B, nc, L, nh, hd).
    """
    b, nc, L, ds = cr.shape
    nh = cum.shape[-1]
    hd = dtx.shape[-1]
    g = b * nc

    crf = cr.reshape(g, L, ds)
    brf = br.reshape(g, L, ds)
    cumf = cum.reshape(g, L, nh)
    dtxf = dtx.reshape(g, L, nh, hd)

    out = pl.pallas_call(
        _kernel,
        grid=(g, nh),
        in_specs=[
            pl.BlockSpec((1, L, ds), lambda i, h: (i, 0, 0)),
            pl.BlockSpec((1, L, ds), lambda i, h: (i, 0, 0)),
            pl.BlockSpec((1, L, 1), lambda i, h: (i, 0, h)),
            pl.BlockSpec((1, L, 1, hd), lambda i, h: (i, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, 1, hd), lambda i, h: (i, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((g, L, nh, hd), dtx.dtype),
        interpret=interpret,
    )(crf, brf, cumf, dtxf)
    return out.reshape(b, nc, L, nh, hd)
