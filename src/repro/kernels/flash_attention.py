"""Blocked flash attention (pl.pallas_call + explicit BlockSpec VMEM tiling).

The online-softmax schedule of models/layers.chunked_attention, expressed as
a Pallas kernel so score blocks live in VMEM and never round-trip HBM — this
removes the S^2 memory traffic that dominates the 32k-prefill memory roofline
term (EXPERIMENTS.md §Perf quantifies the delta from the dry-run HLO).

Grid: (batch*heads, Sq/bq, Sk/bk); the KV axis is innermost so the running
(max, denom, acc) state stays in VMEM scratch across KV blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, d_ref, acc_ref,
            *, scale: float, causal: bool, block_q: int, block_k: int,
            n_k: int):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_idx = pl.program_id(1)
    run = True
    if causal:
        # skip KV blocks strictly above the diagonal
        run = kv_idx * block_k <= (q_idx + 1) * block_q - 1

    @pl.when(run if causal else True)
    def _block():
        q = q_ref[0].astype(jnp.float32)                   # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                   # (bk, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_idx * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = kv_idx * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        d_ref[...] = d_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                   # (bk, hd)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kv_idx == n_k - 1)
    def _flush():
        denom = jnp.maximum(d_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, H, Sk, hd) — GQA repeat happens upstream.

    Returns (B, H, Sq, hd).
    """
    b, h, sq, hd = q.shape
    sk = k.shape[2]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    scale = hd ** -0.5
    n_k = sk // block_k

    qr = q.reshape(b * h, sq, hd)
    kr = k.reshape(b * h, sk, hd)
    vr = v.reshape(b * h, sk, hd)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, n_k=n_k),
        grid=(b * h, sq // block_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),         # running max
            pltpu.VMEM((block_q, 1), jnp.float32),         # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),        # output acc
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, hd)
