"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute with ``interpret=True`` — the kernel
body runs as traced JAX ops, bit-compatible semantics for correctness tests.
On TPU they compile natively.  ``INTERPRET`` is derived from the backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.masked_matmul import masked_matmul as _masked_matmul
from repro.kernels.ssd_scan import ssd_diag as _ssd_diag


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def masked_matmul(x, w, unit_mask, *, block_n: int = 128, block_m: int = 128,
                  block_k: int = 128):
    """Soft-training matmul: y = x @ (w * unit_mask), block-sparse skip.

    unit_mask: (N,) 0/1 — must be block-aligned for exact skipping; the
    helper collapses it to per-block alive flags (a block with ANY live unit
    runs; Helios block-aligned selection makes mask == block structure).
    """
    n = w.shape[1]
    nb = n // block_n
    alive = unit_mask.reshape(nb, block_n).max(axis=1)
    return _masked_matmul(x, w, alive, block_m=block_m, block_n=block_n,
                          block_k=block_k, interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """q,k,v: (B, H, S, hd) -> (B, H, S, hd)."""
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=_interpret())


def ssd_diag(cr, br, cum, dtx):
    return _ssd_diag(cr, br, cum, dtx, interpret=_interpret())


def block_align_mask(unit_mask: jax.Array, block_n: int) -> jax.Array:
    """Round a Helios unit mask UP to block granularity (beyond-paper:
    block-aligned selection keeps the MXU dense within live blocks)."""
    n = unit_mask.shape[-1]
    nb = (n + block_n - 1) // block_n
    pad = nb * block_n - n
    m = jnp.pad(unit_mask, [(0, 0)] * (unit_mask.ndim - 1) + [(0, pad)])
    blocks = m.reshape(m.shape[:-1] + (nb, block_n)).max(axis=-1)
    out = jnp.repeat(blocks, block_n, axis=-1)
    return out[..., :n]
