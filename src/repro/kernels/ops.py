"""Differentiable, padding-safe public wrappers for the Pallas kernels.

This module is the EXECUTION SEAM for kernel-backed soft-training: the model
layers call :func:`masked_dense` / :func:`masked_contract` /
:func:`flash_attention` with ``impl="pallas" | "reference"`` and get

* identical numerics either way (the pallas path multiplies by the unit mask
  so it is exact for ANY 0/1 mask, not just block-aligned ones — dead blocks
  are additionally SKIPPED on the MXU, which is where the Helios volume
  fraction P turns into wall-clock);
* a ``jax.custom_vjp`` on the pallas path whose backward ALSO skips dead
  column blocks (dx via a contraction-masked kernel over dy·mask, dw via the
  column-masked kernel), with EXACTLY-ZERO gradients for masked-out columns
  — the frozen-neuron semantics Helios soft-training requires.

Shapes are padded up to block multiples internally (zero columns are dead
blocks and get skipped), so callers never hit divisibility asserts; unit
masks of any length are handled by :func:`block_align_mask`-style padding.

On CPU (this container) kernels execute with ``interpret=True`` — the kernel
body runs as traced JAX ops, bit-compatible semantics for correctness tests.
On TPU they compile natively.  ``INTERPRET`` is derived from the backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.analysis import contracts as CT
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.masked_matmul import masked_matmul as _mm
from repro.kernels.masked_matmul import masked_matmul_dk as _mm_dk
from repro.kernels.ssd_scan import ssd_diag as _ssd_diag

#: canonical dispatch values for the ``kernels`` / ``impl`` knobs
PALLAS = "pallas"
REFERENCE = "reference"


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _free_block(n: int, cap: int = 128) -> int:
    """Tile size for axes with no mask structure.

    Interpret mode (CPU) has no alignment constraints, so small/ragged dims
    get one exact-size tile (no padding waste).  Native Mosaic compilation
    requires hardware-aligned tiles — there the full ``cap`` (128, lane- and
    sublane-aligned) is used and :func:`_pad_axis` rounds the operand up.
    """
    if not _interpret():
        return cap
    return min(cap, max(n, 1))


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# block-aligned masks
# ---------------------------------------------------------------------------


def block_align_mask(unit_mask: jax.Array, block_n: int) -> jax.Array:
    """Round a Helios unit mask UP to block granularity (beyond-paper:
    block-aligned selection keeps the MXU dense within live blocks).

    Idempotent; output is a superset of the input mask and block-constant
    (every length-``block_n`` group of the padded mask is all-0 or all-1) —
    properties pinned by tests/test_kernel_softtrain.py.
    """
    n = unit_mask.shape[-1]
    nb = (n + block_n - 1) // block_n
    pad = nb * block_n - n
    m = jnp.pad(unit_mask, [(0, 0)] * (unit_mask.ndim - 1) + [(0, pad)])
    blocks = m.reshape(m.shape[:-1] + (nb, block_n)).max(axis=-1)
    out = jnp.repeat(blocks, block_n, axis=-1)
    return out[..., :n]


def _block_alive(unit_mask: jax.Array, block_n: int) -> jax.Array:
    """(N,) 0/1 mask -> (ceil(N/bn),) per-block alive flags (a block with ANY
    live unit runs; padding columns are dead)."""
    m = _pad_axis(unit_mask, 0, block_n)
    return m.reshape(-1, block_n).max(axis=1)


# ---------------------------------------------------------------------------
# masked matmul (column-block skip) + its VJP
# ---------------------------------------------------------------------------


def _mm_padded(x, w, unit_mask, block_n):
    """Column-masked kernel over padded operands; exact ``x @ (w·mask)``."""
    m, k = x.shape
    n = w.shape[1]
    bm, bk = _free_block(m), _free_block(k)
    xp = _pad_axis(_pad_axis(x, 0, bm), 1, bk)
    wp = _pad_axis(_pad_axis(w, 0, bk), 1, block_n)
    alive = _block_alive(unit_mask, block_n)
    y = _mm(xp, wp, alive, block_m=bm, block_n=block_n, block_k=bk,
            interpret=_interpret())[:m, :n]
    # multiply by the unit mask: restores exactness for masks that are not
    # block-constant (a live block may still contain dead units) and pins
    # dead columns to bit-zero even on the padded path
    return y * unit_mask.astype(y.dtype)[None, :]


def _mm_dk_padded(x, w, unit_mask, block_n):
    """Contraction-masked kernel: ``x @ w`` skipping dead K-blocks.  Exact
    when the skipped columns of ``x`` are zero (masked activations or
    masked cotangents)."""
    m, k = x.shape
    n = w.shape[1]
    bm, bn = _free_block(m), _free_block(n)
    xp = _pad_axis(_pad_axis(x, 0, bm), 1, block_n)
    wp = _pad_axis(_pad_axis(w, 0, block_n), 1, bn)
    alive = _block_alive(unit_mask, block_n)
    return _mm_dk(xp, wp, alive, block_m=bm, block_n=bn, block_k=block_n,
                  interpret=_interpret())[:m, :n]


@functools.lru_cache(maxsize=None)
def _masked_dense_pallas(block_n: int):
    """custom_vjp'd ``y = x @ (w · mask)`` at one mask-block granularity.

    Backward: dx = (dy·mask) @ Wᵀ with dead N-blocks skipped in the
    contraction; dw = Xᵀ @ (dy·mask) with dead column blocks skipped and
    masked columns EXACTLY zero.  The mask itself gets a zero cotangent
    (selection is not differentiable).
    """

    @jax.custom_vjp
    def fn(x, w, unit_mask):
        return _mm_padded(x, w, unit_mask, block_n)

    def fwd(x, w, unit_mask):
        return fn(x, w, unit_mask), (x, w, unit_mask)

    def bwd(res, dy):
        x, w, unit_mask = res
        dym = dy * unit_mask.astype(dy.dtype)[None, :]
        dx = _mm_dk_padded(dym, w.T, unit_mask, block_n)
        dw = _mm_padded(x.T, dym, unit_mask, block_n)
        return dx, dw, jnp.zeros_like(unit_mask)

    fn.defvjp(fwd, bwd)
    return fn


@functools.lru_cache(maxsize=None)
def _masked_contract_pallas(block_n: int):
    """custom_vjp'd ``y = h @ w`` where the CONTRACTION dim is unit-masked.

    Exact whenever masked columns of ``h`` are zero (guaranteed when ``h``
    came through :func:`masked_dense`).  Backward: dh = dy @ Wᵀ with masked
    columns zeroed (they are dead downstream anyway — zeroing keeps the
    skip structural); dw = hᵀ @ dy with dead ROW blocks skipped and masked
    rows exactly zero.
    """

    @jax.custom_vjp
    def fn(h, w, unit_mask):
        return _mm_dk_padded(h * unit_mask.astype(h.dtype)[None, :], w,
                             unit_mask, block_n)

    def fwd(h, w, unit_mask):
        return fn(h, w, unit_mask), (h, w, unit_mask)

    def bwd(res, dy):
        h, w, unit_mask = res
        # dh = dy @ wᵀ, masked columns (dh's N axis = the masked dim) zeroed
        dh = _mm_padded(dy, w.T, unit_mask, block_n)
        # dw = hᵀ @ dy, rows = masked dim: compute dwᵀ with the column-masked
        # kernel, so dead rows of dw are skipped AND exactly zero
        dw = _mm_padded(dy.T, h, unit_mask, block_n).T
        return dh, dw, jnp.zeros_like(unit_mask)

    fn.defvjp(fwd, bwd)
    return fn


def _collapse(x):
    """(..., K) -> (M, K) view + a restorer for the leading dims."""
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lambda y: y.reshape(lead + y.shape[-1:])


def _masked_dense_pre(x, w, unit_mask, **kwargs):
    """Kernel precondition (shape-level, jit-safe): x (..., K) contracts
    with w (K, N); unit_mask masks w's OUTPUT axis (N,)."""
    if w.ndim != 2 or x.shape[-1] != w.shape[0]:
        raise CT.ContractError(
            f"masked_dense: x (..., K={x.shape[-1]}) incompatible with "
            f"w {w.shape} (want (K, N))")
    if unit_mask.shape != (w.shape[1],):
        raise CT.ContractError(
            f"masked_dense: unit_mask {unit_mask.shape} must be "
            f"(N,) = ({w.shape[1]},) — it masks w's output axis")


@CT.contract(pre=_masked_dense_pre)
def masked_dense(x, w, unit_mask, *, impl: str = REFERENCE,
                 block_n: int = 128):
    """Soft-training dense layer: ``y = x @ (w · unit_mask[None, :])``.

    x: (..., K); w: (K, N); unit_mask: (N,) float 0/1.  ``impl="pallas"``
    runs the block-sparse kernel pair (fwd+bwd skip dead column blocks);
    ``impl="reference"`` is the plain-jnp semantics the kernels are pinned
    against.  Masked columns of y — and of every gradient — are exactly 0.
    """
    if impl != PALLAS:
        return x @ (w * unit_mask.astype(w.dtype)[None, :])
    x2, restore = _collapse(x)
    return restore(_masked_dense_pallas(block_n)(x2, w, unit_mask))


def _masked_contract_pre(h, w, unit_mask, **kwargs):
    """Kernel precondition: h (..., N) contracts with w (N, K) over the
    MASKED axis; unit_mask is (N,)."""
    if w.ndim != 2 or h.shape[-1] != w.shape[0]:
        raise CT.ContractError(
            f"masked_contract: h (..., N={h.shape[-1]}) incompatible "
            f"with w {w.shape} (want (N, K))")
    if unit_mask.shape != (w.shape[0],):
        raise CT.ContractError(
            f"masked_contract: unit_mask {unit_mask.shape} must be "
            f"(N,) = ({w.shape[0]},) — it masks the contraction axis")


@CT.contract(pre=_masked_contract_pre)
def masked_contract(h, w, unit_mask, *, impl: str = REFERENCE,
                    block_n: int = 128):
    """Second half of a masked MLP: ``y = (h · unit_mask) @ w`` where the
    contraction dimension is the masked one.  h: (..., N); w: (N, K);
    unit_mask: (N,).  The pallas path skips dead contraction blocks in the
    forward and dead rows of dw in the backward (exact zeros)."""
    if impl != PALLAS:
        return (h * unit_mask.astype(h.dtype)) @ w
    h2, restore = _collapse(h)
    return restore(_masked_contract_pallas(block_n)(h2, w, unit_mask))


def masked_matmul(x, w, unit_mask, *, block_n: int = 128):
    """Soft-training matmul: y = x @ (w * unit_mask), block-sparse skip.

    unit_mask: (N,) 0/1 of ANY length — masks whose length is not a multiple
    of ``block_n`` are padded (zero-padding = dead blocks), not rejected,
    and masks that are not block-constant stay exact because the kernel
    output is multiplied by the unit mask.  Block-aligned selection
    (:func:`block_align_mask`) makes the skip structural.  The M/K tile
    sizes are derived from the shapes (:func:`_free_block`).
    """
    return _mm_padded(x, w, unit_mask, block_n)


# ---------------------------------------------------------------------------
# flash attention + recompute VJP
# ---------------------------------------------------------------------------


def _flash_padded(q, k, v, causal, block_q, block_k):
    """Kernel forward with the sequence axes padded to block multiples.

    q, k, v: (B, H, S, hd).  Padded KEYS sit at the end of the sequence, so
    under the causal mask (with Sq == Sk, the self-attention training case)
    no real query ever attends one; padded QUERY rows are sliced off.  A
    causal CROSS-length call would let trailing queries attend zero-padded
    keys, so it is rejected.  (The non-causal path only pads queries.)
    """
    b, h, sq, hd = q.shape
    sk = k.shape[2]
    bq = _free_block(sq, block_q)
    bk = _free_block(sk, block_k)
    qp = _pad_axis(q, 2, bq)
    if causal:
        assert sq == sk, (
            f"causal flash kernel needs Sq == Sk (got {sq} vs {sk}): with "
            "key padding a trailing query would attend padded keys")
        kp, vp = _pad_axis(k, 2, bk), _pad_axis(v, 2, bk)
    else:
        assert sk % bk == 0, (
            f"non-causal flash kernel needs Sk % {bk} == 0 (got {sk}): "
            "padded keys would receive attention weight")
        kp, vp = k, v
    out = _flash(qp, kp, vp, causal=causal, block_q=bq, block_k=bk,
                 interpret=_interpret())
    return out[:, :, :sq]


@functools.lru_cache(maxsize=None)
def _flash_diff(causal: bool, block_q: int, block_k: int):
    """custom_vjp'd flash attention: pallas forward, checkpointed-recompute
    backward (the reference attention is re-evaluated and differentiated —
    O(S²) scores live only inside the VJP, never across it; a native Pallas
    backward kernel is the remaining TPU optimization)."""
    from repro.kernels import ref

    @jax.custom_vjp
    def fn(q, k, v):
        return _flash_padded(q, k, v, causal, block_q, block_k)

    def fwd(q, k, v):
        return fn(q, k, v), (q, k, v)

    def bwd(res, dy):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda q_, k_, v_: ref.flash_attention_ref(q_, k_, v_,
                                                       causal=causal),
            q, k, v)
        return vjp(dy)

    fn.defvjp(fwd, bwd)
    return fn


def _flash_attention_pre(q, k, v, *, causal: bool = True, **kwargs):
    """Attention precondition: (B, H, S, hd) operands, matching k/v
    sequence lengths, and Sq == Sk under the causal mask (key padding
    would otherwise leak attention onto padded keys)."""
    if not (q.ndim == k.ndim == v.ndim == 4):
        raise CT.ContractError(
            f"flash_attention: q/k/v must be (B, H, S, hd), got "
            f"{q.shape}/{k.shape}/{v.shape}")
    if k.shape != v.shape or q.shape[:2] != k.shape[:2] or \
            q.shape[3] != k.shape[3]:
        raise CT.ContractError(
            f"flash_attention: incompatible q {q.shape} vs k {k.shape} "
            f"vs v {v.shape}")
    if causal and q.shape[2] != k.shape[2]:
        raise CT.ContractError(
            f"flash_attention: causal needs Sq == Sk "
            f"(got {q.shape[2]} vs {k.shape[2]})")


@CT.contract(pre=_flash_attention_pre)
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """q,k,v: (B, H, S, hd) -> (B, H, S, hd).  Differentiable (recompute
    VJP) and padding-safe: any SELF-attention length works under ``causal``
    (Sq == Sk required there; non-causal allows cross-length but needs
    block-aligned keys)."""
    return _flash_diff(causal, block_q, block_k)(q, k, v)


def ssd_diag(cr, br, cum, dtx):
    return _ssd_diag(cr, br, cum, dtx, interpret=_interpret())
