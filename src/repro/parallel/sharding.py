"""Logical-axis sharding rule engine (MaxText-style) with divisibility-aware
fallback.

Every parameter carries logical axis names (models/module.py).  RULES maps a
logical axis to candidate mesh axes in priority order; the solver assigns the
first candidate that (a) is present in the mesh, (b) still unused within this
tensor's spec, and (c) divides the dim size — otherwise the dim replicates.
This is how e.g. internvl2's 14 heads fall back to replication while its
d_ff = 4864 = 16*304 tensor-shards (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: logical axis -> mesh-axis candidates (first fit wins).
#: "embed" shards over data = FSDP; heads/mlp/experts over model = TP/EP.
RULES: dict = {
    "vocab": ("model",),
    "embed": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "ssm_heads": ("model",),
    "q_lora": ("model",),
    "kv_lora": (),
    "head_dim": (),
    "hd2": (),
    "conv_k": (),
    "ssm_state": (),
    "layers": (),
    "filters": (),
    None: (),
}

#: batch/seq rules for activations & caches
BATCH_AXES = ("pod", "data")

#: params below this size replicate their "embed" dim (no FSDP): the weight
#: all-gathers FSDP induces cost more than the HBM they save on small models.
FSDP_THRESHOLD = 8e9


def rules_for(cfg, kind: str = "train") -> dict:
    """Arch/workload-dependent rules.

    * FSDP (embed -> data) only for big models (small models pay more in
      weight all-gathers than they save in HBM).
    * decode with a SMALL expert pool replicates experts: dispatching a
      few hundred tokens through expert-parallel all-to-alls costs more
      than holding a local expert copy (EXPERIMENTS.md §Perf cell B).
    """
    rules = dict(RULES)
    if cfg.n_params() < FSDP_THRESHOLD:
        rules["embed"] = ()
        rules["q_lora"] = ("model",)
    if kind == "decode" and cfg.family == "moe":
        expert_bytes = (cfg.num_experts * 3 * cfg.d_model * cfg.moe_d_ff *
                        cfg.num_layers * 2)
        if expert_bytes < 4e9:                       # fits HBM comfortably
            rules["experts"] = ()
    return rules


def _mesh_size(mesh, axis: str) -> int:
    """Axis size; works for both Mesh and AbstractMesh."""
    return dict(mesh.shape).get(axis, 0)


def spec_for_axes(axes: Tuple[Optional[str], ...],
                  shape: Tuple[int, ...],
                  mesh: Mesh,
                  rules: Optional[dict] = None) -> P:
    """PartitionSpec for one tensor from its logical axes + concrete shape."""
    rules = rules or RULES
    used: set = set()
    entries = []
    for name, dim in zip(axes, shape):
        assigned = None
        for cand in rules.get(name, ()):
            size = _mesh_size(mesh, cand)
            if size and cand not in used and dim % size == 0 and dim >= size:
                assigned = cand
                used.add(cand)
                break
        entries.append(assigned)
    return P(*entries)


def param_shardings(axes_tree, shape_tree, mesh: Mesh, rules=None):
    """NamedSharding tree for a whole param pytree.

    ``shape_tree`` is any tree of arrays / ShapeDtypeStructs aligned with
    ``axes_tree``.
    """
    def one(axes, leaf):
        return NamedSharding(mesh, spec_for_axes(axes, leaf.shape, mesh,
                                                 rules))

    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(e, (str, type(None))) for e in x))


def batch_spec(shape: Tuple[int, ...], mesh: Mesh,
               batch_size: int) -> P:
    """Shard the leading batch dim over ("pod","data")."""
    axes_avail = [a for a in BATCH_AXES if _mesh_size(mesh, a)]
    prod = int(np.prod([_mesh_size(mesh, a) for a in axes_avail]) or 1)
    if shape and shape[0] == batch_size and batch_size % prod == 0 and prod > 1:
        return P(tuple(axes_avail), *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def batch_shardings(batch_tree, mesh: Mesh, batch_size: int):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(l.shape, mesh, batch_size)),
        batch_tree)


def cache_spec(shape: Tuple[int, ...], mesh: Mesh, batch: int, seq: int,
               kv_heads: int) -> P:
    """KV/SSM-cache sharding for serve cells.

    Priority: batch dim over ("pod","data"); if batch is too small
    (long-context batch=1), the SEQUENCE dim takes the data axes instead
    (sequence-parallel cache).  A kv-heads-sized dim takes "model" when
    divisible; otherwise the sequence dim absorbs "model" too (cache-sequence
    sharding, standard for GQA models whose kv_heads < TP degree).
    """
    dims = list(shape)
    entries: list = [None] * len(dims)
    axes_avail = [a for a in BATCH_AXES if _mesh_size(mesh, a)]
    dprod = int(np.prod([_mesh_size(mesh, a) for a in axes_avail]) or 1)
    msize = _mesh_size(mesh, "model")

    batch_dim = next((i for i, d in enumerate(dims) if d == batch), None)
    seq_dim = next((i for i, d in enumerate(dims)
                    if d == seq and i != batch_dim), None)
    kv_dim = next((i for i, d in enumerate(dims)
                   if d == kv_heads and i not in (batch_dim, seq_dim)), None)

    data_used = False
    if batch_dim is not None and batch % dprod == 0 and dprod > 1:
        entries[batch_dim] = tuple(axes_avail)
        data_used = True
    elif seq_dim is not None and seq % dprod == 0:
        entries[seq_dim] = tuple(axes_avail)
        data_used = True

    if msize:
        if kv_dim is not None and kv_heads % msize == 0 and kv_heads >= msize:
            entries[kv_dim] = "model"
        elif seq_dim is not None and entries[seq_dim] is None and \
                seq % msize == 0:
            entries[seq_dim] = "model"
        elif seq_dim is not None and data_used and \
                entries[seq_dim] == tuple(axes_avail) and batch_dim is None:
            pass                                       # seq already on data
    return P(*entries)


def cache_shardings(cache_tree, mesh: Mesh, batch: int, seq: int,
                    kv_heads: int):
    return jax.tree.map(
        lambda l: NamedSharding(
            mesh, cache_spec(l.shape, mesh, batch, seq, kv_heads)),
        cache_tree)


def replicated(tree, mesh: Mesh):
    return jax.tree.map(lambda l: NamedSharding(mesh, P()), tree)
