"""HLO analysis: collective-traffic extraction + roofline terms.

``cost_analysis()`` gives HLO FLOPs/bytes but NOT collective bytes — those
are parsed from the compiled HLO text: we sum the output-operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction (per-device view, as GSPMD emits it).

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

#: matches e.g. ``f32[128,1024]{1,0}`` or ``bf16[4096]``
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Older JAX returned one flat dict; current JAX returns a LIST with one
    dict per computation (and either may be None/empty).  Callers always
    want the flat {metric: float} view of the main program.
    """
    cost = compiled.cost_analysis()
    if not cost:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _shape_bytes(type_str: str) -> int:
    """Total bytes of every array literal in an HLO type string (handles
    tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind byte totals from compiled (post-SPMD) HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # instruction lines look like:  %x = f32[..] all-reduce(...)
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[^\s]+)\s+"
                     r"([\w\-]+)", s)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-start"):
                out[kind] += _shape_bytes(type_str)
                break
    return out


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one (arch x shape x mesh) cell."""

    flops: float                 # HLO FLOPs (per device)
    hbm_bytes: float             # HLO bytes accessed (per device)
    coll_bytes: float            # collective bytes (per device)
    num_devices: int
    model_flops: float           # 6*N*D (analytic, GLOBAL)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x devices): remat/redundancy waste."""
        total = self.flops * self.num_devices
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        denom = self.step_time * PEAK_FLOPS * self.num_devices
        return self.model_flops / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_per_dev": self.flops,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_mfu": self.mfu,
        }


def exact_param_counts(cfg) -> tuple[float, float]:
    """(total, active) param counts from the REAL spec (not the analytic
    estimate): MoE active = total - inactive routed expert fraction."""
    from repro.models import build
    from repro.models.module import param_count
    total = float(param_count(build(cfg).spec))
    active = total
    if cfg.family == "moe":
        n_moe = cfg.num_layers - cfg.first_k_dense
        routed = float(cfg.num_experts) * 3 * cfg.d_model * cfg.moe_d_ff * n_moe
        active_routed = routed * cfg.num_experts_per_tok / cfg.num_experts
        active = total - routed + active_routed
    return total, active


def model_flops_for_cell(cfg, shape) -> float:
    """6*N*D for training, 2*N*D for inference (N = active params, exact)."""
    _, n = exact_param_counts(cfg)
    d = shape.tokens_per_step
    if shape.kind == "train":
        return 6.0 * n * d
    return 2.0 * n * d            # prefill / decode (one token per sequence)
