"""Trip-count-weighted HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a while-loop BODY ONCE, so every
``lax.scan`` (layers, microbatches, KV chunks, recurrences) under-reports
FLOPs/bytes/collectives by its trip count.  This module re-walks the
post-optimization HLO text: each computation's cost is summed per
instruction, and ``while`` ops multiply (body + cond) cost by the
``known_trip_count`` XLA annotates in backend_config.

FLOP rules follow HloCostAnalysis: dot = 2 * out_elems * contracted_elems,
elementwise = out_elems, reduce = in_elems; bytes = operands + output.
Collectives (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute) accumulate their shape bytes, weighted by enclosing trip
counts — which the flat text scan in hlo_analysis.collective_bytes misses.

Validated against cost_analysis on loop-free programs (tests/test_hlo_cost.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "tanh", "exponential", "log", "negate", "abs", "rsqrt", "sqrt", "sign",
    "compare", "select", "and", "or", "xor", "not", "convert", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "clamp", "sine",
    "cosine", "logistic", "exponential-minus-one", "log-plus-one", "atan2",
    "remainder", "is-finite", "erf", "cbrt", "tan",
}

def _parse_instr_line(line: str) -> Optional["Instr"]:
    """Procedural instruction parse: handles tuple types with /*index=N*/
    comments (which contain '=' and break naive regexes)."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    eq = s.find(" = ")
    if eq < 0 or not s.startswith("%") and not s[:1].isalpha():
        return None
    name = s[:eq].strip().lstrip("%")
    rest = s[eq + 3:].strip()
    if rest.startswith("("):                      # tuple type: balanced scan
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str, rest2 = rest[:end + 1], rest[end + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest2 = rest[:sp], rest[sp + 1:].strip()
    p = rest2.find("(")
    if p <= 0:
        return None
    op = rest2[:p].strip()
    if not re.fullmatch(r"[\w\-]+", op):
        return None
    return Instr(name, type_str, op, rest2[p + 1:])

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")

_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

#: ops that move no HBM bytes (views / metadata / control flow plumbing)
_NO_BYTES = {"get-tuple-element", "tuple", "parameter", "constant", "bitcast",
             "after-all", "partition-id", "replica-id", "opt-barrier"}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """(total elements, total bytes) over all array literals in a type."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str          # operand list + attributes (everything after '(')


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symtab: Dict[str, str]      # instr name -> type string


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and "{" in line:
                cur = Computation(m.group(1), [], {})
            continue
        s = line.strip()
        if s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        inst = _parse_instr_line(line)
        if inst:
            cur.instrs.append(inst)
            cur.symtab[inst.name] = inst.type_str
    return comps


def _dot_flops(inst: Instr, symtab: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(inst.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.rest)
    ops = _OPERAND_RE.findall(inst.rest)
    if not m or not ops:
        return 2.0 * out_elems
    lhs_type = symtab.get(ops[0], "")
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 2.0 * out_elems
    dims = [int(d) for d in sm.group(2).split(",") if d]
    contracted = 1
    for i in (int(x) for x in m.group(1).split(",") if x):
        if i < len(dims):
            contracted *= dims[i]
    return 2.0 * out_elems * contracted


def _operand_bytes(inst: Instr, symtab: Dict[str, str]) -> int:
    total = 0
    # operands appear before the first '),'; attributes reference %comps too,
    # so restrict to the operand parenthesis segment.
    depth = 1
    end = 0
    for i, ch in enumerate(inst.rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    seg = inst.rest[:end] if end else inst.rest
    for op_name in _OPERAND_RE.findall(seg):
        t = symtab.get(op_name)
        if t:
            total += _shape_elems_bytes(t)[1]
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Dict[str, float]] = None
    transcendental: float = 0.0

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in _COLLECTIVES}

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendental += other.transcendental * mult
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * mult


class Analyzer:
    def __init__(self, hlo_text: str):
        self.comps = parse_module(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self._fusion_bytes_memo: Dict[str, float] = {}
        self.entry = self._find_entry(hlo_text)

    @staticmethod
    def _find_entry(text: str) -> Optional[str]:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
                if m:
                    return m.group(1)
        return None

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()            # cycle guard
        comp = self.comps.get(name)
        if comp is None:
            return self._memo[name]
        total = Cost()
        for inst in comp.instrs:
            total.add(self._instr_cost(inst, comp))
        self._memo[name] = total
        return total

    def _fusion_input_bytes(self, called: str) -> float:
        """Input bytes of one fusion: a parameter consumed ONLY by
        slice-type ops contributes the sliced bytes, not its full size
        (scan bodies dynamic-slice stacked layer params -> one layer per
        trip).  Mirrors HloCostAnalysis's fusion handling."""
        if called in self._fusion_bytes_memo:
            return self._fusion_bytes_memo[called]
        comp = self.comps.get(called)
        if comp is None:
            return 0.0
        total = 0.0
        sliced_ops = ("dynamic-slice", "slice", "gather")
        for p in comp.instrs:
            if p.op != "parameter":
                continue
            _, p_bytes = _shape_elems_bytes(p.type_str)
            consumers = [i for i in comp.instrs
                         if i is not p and p.name in _OPERAND_RE.findall(
                             i.rest.split("),")[0])]
            if consumers and all(cn.op in sliced_ops for cn in consumers):
                total += sum(_shape_elems_bytes(cn.type_str)[1]
                             for cn in consumers)
            else:
                total += p_bytes
        self._fusion_bytes_memo[called] = total
        return total

    def _instr_cost(self, inst: Instr, comp: Computation) -> Cost:
        c = Cost()
        op = inst.op
        out_elems, out_bytes = _shape_elems_bytes(inst.type_str)

        if op == "while":
            trip = 1
            m = _TRIP_RE.search(inst.rest)
            if m:
                trip = int(m.group(1))
            body = _BODY_RE.search(inst.rest)
            cond = _COND_RE.search(inst.rest)
            if body:
                c.add(self.comp_cost(body.group(1)), trip)
            if cond:
                c.add(self.comp_cost(cond.group(1)), trip)
            return c
        if op == "fusion":
            m = _CALLS_RE.search(inst.rest)
            if m:
                inner = self.comp_cost(m.group(1))
                # fused ops never touch HBM: count inner FLOPs/collectives,
                # but bytes are the fusion boundary only (HloCostAnalysis).
                c.flops += inner.flops
                c.transcendental += inner.transcendental
                for k in _COLLECTIVES:
                    c.coll[k] += inner.coll[k]
                c.bytes += out_bytes + self._fusion_input_bytes(m.group(1))
            else:
                c.bytes += out_bytes + _operand_bytes(inst, comp.symtab)
            return c
        if op in _NO_BYTES:
            return c
        if op in ("call", "conditional", "sort", "scatter", "reduce",
                  "reduce-window", "select-and-scatter", "map",
                  "all-reduce", "reduce-scatter"):
            # ops with sub-computations (to_apply) — count the sub once per
            # output element for reduce-likes is overkill; HloCostAnalysis
            # treats reduce as in_elems flops: approximate below, and still
            # descend into call/conditional bodies.
            if op in ("call", "conditional"):
                for sub in _CALL_RE.findall(inst.rest):
                    c.add(self.comp_cost(sub))
        for kind in _COLLECTIVES:
            if op == kind or op == kind + "-start":
                wire = out_bytes
                # XLA's CPU backend PROMOTES bf16 reductions to f32
                # ("to_apply=%add..._promoted"): the wire dtype on TPU is
                # bf16 — count half the promoted f32 bytes.
                if "_promoted" in inst.rest and "f32[" in inst.type_str:
                    wire = out_bytes / 2.0
                c.coll[kind] += wire
                break
        if op == "dot":
            c.flops += _dot_flops(inst, comp.symtab)
        elif op == "convolution":
            # rough: 2 * out * (kernel elems) — fine, CNNs are not dry-run cells
            c.flops += 2.0 * out_elems
        elif op in _ELEMWISE:
            c.flops += out_elems
            if op in ("tanh", "exponential", "log", "logistic", "power",
                      "sine", "cosine", "erf", "tan"):
                c.transcendental += out_elems
        elif op in ("reduce", "reduce-window"):
            c.flops += _operand_bytes(inst, comp.symtab) / 4.0  # ~in_elems
        elif op == "all-reduce" or op == "all-reduce-start":
            c.flops += out_elems

        # ---- bytes: sliced/indexed accesses only touch what they produce,
        # NOT the whole operand (a scan body dynamic-slicing stacked layer
        # params reads one layer per trip, not the full stack) ----
        if op in ("dynamic-slice", "slice", "gather"):
            c.bytes += 2.0 * out_bytes
        elif op == "dynamic-update-slice":
            ops_ = _OPERAND_RE.findall(inst.rest.split("),")[0])
            upd = comp.symtab.get(ops_[1], "") if len(ops_) > 1 else ""
            c.bytes += 2.0 * _shape_elems_bytes(upd)[1]
        elif op == "scatter":
            ops_ = _OPERAND_RE.findall(inst.rest.split("),")[0])
            upd = comp.symtab.get(ops_[-1], "") if ops_ else ""
            c.bytes += 2.0 * _shape_elems_bytes(upd)[1] + out_bytes
        else:
            c.bytes += out_bytes + _operand_bytes(inst, comp.symtab)
        return c

    def total(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.comp_cost(self.entry)


def weighted_cost(hlo_text: str) -> dict:
    t = Analyzer(hlo_text).total()
    return {"flops": t.flops, "bytes": t.bytes,
            "collectives": dict(t.coll),
            "collective_bytes": sum(t.coll.values()),
            "transcendental": t.transcendental}


def pattern_bytes(hlo_text: str, pattern: str) -> float:
    """Trip-weighted HBM bytes of instructions whose metadata op_name
    contains ``pattern`` (jax.named_scope names appear there).

    Used for the flash-attention roofline adjustment: the bytes attributed
    to the "chunked_attention" scope are the S^2 score-block traffic that
    the Pallas kernel (kernels/flash_attention.py) keeps in VMEM.
    """
    a = Analyzer(hlo_text)
    total = 0.0

    def walk(name: str, weight: float, seen):
        nonlocal total
        if name in seen:
            return
        comp = a.comps.get(name)
        if comp is None:
            return
        for inst in comp.instrs:
            if inst.op == "while":
                trip = 1
                m = _TRIP_RE.search(inst.rest)
                if m:
                    trip = int(m.group(1))
                body = _BODY_RE.search(inst.rest)
                cond = _COND_RE.search(inst.rest)
                if body:
                    walk(body.group(1), weight * trip, seen)
                if cond:
                    walk(cond.group(1), weight * trip, seen)
                continue
            if pattern in inst.rest:
                total += a._instr_cost(inst, comp).bytes * weight

    walk(a.entry, 1.0, set())
    return total
