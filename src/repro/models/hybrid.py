"""Hybrid Mamba2 + shared-attention assembly (zamba2).

38 Mamba2 layers; ONE shared transformer block (weights reused) applied every
``attn_every`` layers — each invocation keeps its own KV cache (activations
differ even though weights are shared).  Zamba2's per-invocation LoRA on the
shared block is omitted (noted in DESIGN.md §7).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm
from repro.models.module import stack


def hybrid_spec(cfg: ModelConfig):
    return {
        "embed": L.embed_spec(cfg.padded_vocab, cfg.d_model, True),
        "mamba_norms": stack(L.norm_spec(cfg.d_model, cfg.norm), cfg.num_layers),
        "mamba": stack(ssm.mamba2_spec(cfg), cfg.num_layers),
        "shared_attn": {
            "attn_norm": L.norm_spec(cfg.d_model, cfg.norm),
            "attn": L.attention_spec(cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.resolved_head_dim,
                                     cfg.qkv_bias),
            "mlp_norm": L.norm_spec(cfg.d_model, cfg.norm),
            "mlp": L.mlp_spec(cfg.d_model, cfg.d_ff, cfg.activation),
        },
        "final_norm": L.norm_spec(cfg.d_model, cfg.norm),
    }


def _n_attn(cfg) -> int:
    return (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every


def mask_schema(cfg: ModelConfig) -> Dict[str, tuple]:
    nh = cfg.ssm_expand * cfg.d_model // cfg.ssm_head_dim
    return {
        "ssm_heads": (cfg.num_layers, nh),
        "heads": (1, cfg.num_heads),          # shared block
        "mlp": (1, cfg.d_ff),
    }


def _attn_block(p, x, positions, cfg, rt, masks, cache=None, pos=None):
    hm = None if masks is None or "heads" not in masks else masks["heads"][0]
    mm = None if masks is None or "mlp" not in masks else masks["mlp"][0]
    h = L.apply_norm(p["attn_norm"], x, cfg.norm)
    if cache is None:
        a = L.attention_fwd(p["attn"], h, positions, theta=cfg.rope_theta,
                            impl=rt["attn_impl"], head_mask=hm)
        kv = None
    elif pos is None:                          # prefill: build cache
        a, kv = L.attention_prefill(p["attn"], h, positions,
                                    theta=cfg.rope_theta, impl=rt["attn_impl"],
                                    head_mask=hm)
    else:                                      # decode
        a, kv = L.attention_decode(p["attn"], h, cache, pos,
                                   theta=cfg.rope_theta, head_mask=hm)
    x = x + a
    h2 = L.apply_norm(p["mlp_norm"], x, cfg.norm)
    return x + L.mlp_fwd(p["mlp"], h2, cfg.activation, unit_mask=mm), kv


def _run(params, x, cfg, rt, masks, mode, cache=None, pos=None):
    """mode: train | prefill | decode.  Returns (x, new_cache)."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s)) if pos is None \
        else jnp.full((b, s), pos, jnp.int32)
    new_ssm, new_kv = [], []
    ai = 0
    for i in range(cfg.num_layers):
        if cfg.attn_every and i % cfg.attn_every == 0:
            kv_in = None if cache is None else cache["attn"][ai]
            want_cache = mode != "train"
            x, kv = _attn_block(params["shared_attn"], x, positions, cfg, rt,
                                masks,
                                cache=kv_in if mode == "decode" else (
                                    {} if want_cache else None),
                                pos=pos if mode == "decode" else None)
            if want_cache:
                new_kv.append(kv)
            ai += 1
        p = jax.tree.map(lambda t: t[i], params["mamba"])
        pn = jax.tree.map(lambda t: t[i], params["mamba_norms"])
        hm = None if masks is None or "ssm_heads" not in masks else \
            masks["ssm_heads"][i]
        h = L.apply_norm(pn, x, cfg.norm)
        if mode == "decode":
            y, st = ssm.mamba2_decode(p, h, cache["ssm"][i], cfg, head_mask=hm)
            new_ssm.append(st)
        elif mode == "prefill":
            y, st = ssm.mamba2_fwd(p, h, cfg, head_mask=hm, return_cache=True)
            new_ssm.append(st)
        else:
            y = ssm.mamba2_fwd(p, h, cfg, head_mask=hm)
        x = x + y
    if mode == "train":
        return x, None
    return x, {"ssm": new_ssm, "attn": new_kv}


def hybrid_loss(params, batch, cfg: ModelConfig, rt, masks=None,
                active_mlp_idx=None):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    x = L.constrain(x, rt.get("act_spec"))
    x, _ = _run(params, x, cfg, rt, masks, "train")
    h = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.constrain(L.unembed(params["embed"], h),
                         rt.get("logits_spec"))
    mask = jnp.ones(tokens.shape, logits.dtype).at[:, -1].set(0.0)
    return L.cross_entropy_loss(logits[:, :-1], tokens[:, 1:], mask[:, :-1])


def hybrid_prefill(params, batch, cfg: ModelConfig, rt, masks=None):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    x, cache = _run(params, x, cfg, rt, masks, "prefill")
    h = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], h[:, -1:])
    cache["pos"] = jnp.array(tokens.shape[1], jnp.int32)
    return logits[:, 0], cache


def hybrid_decode(params, token, cache, cfg: ModelConfig, rt, masks=None):
    x = L.embed(params["embed"], token)
    pos = cache["pos"]
    x, new_cache = _run(params, x, cfg, rt, masks, "decode", cache=cache,
                        pos=pos)
    h = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], h)
    new_cache["pos"] = pos + 1
    return logits[:, 0], new_cache
