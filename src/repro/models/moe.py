"""Mixture-of-Experts with capacity-based grouped dispatch.

Two execution paths:

* ``grouped`` (default): tokens are sorted by routed expert id into E groups
  of static capacity C (overflow dropped, standard TPU practice).  Compiled
  FLOPs are proportional to ACTIVE params (top-k), which is what the roofline
  MODEL_FLOPS/HLO_FLOPs ratio checks.  Dispatch is vmapped over ``moe_groups``
  token groups so the sort/scatter stays LOCAL to a data-parallel shard group
  and GSPMD only inserts the expert-parallel collectives (DESIGN.md §5).
* ``dense``: every expert sees every token, masked combine.  Exact reference —
  used as the oracle in tests and for tiny smoke configs.

Helios hook: ``expert_mask`` (float 0/1 over E) zeroes router probabilities of
inactive experts before top-k — expert-level soft-training (rotating which
experts train), the natural unit for granite/deepseek-v2 (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.module import P
from repro.models.layers import mlp_fwd, mlp_spec


def moe_spec(cfg):
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    spec = {
        "router": P((d, e), ("embed", "experts"), scale=0.02),
        "wi": P((e, d, ff), ("experts", "embed", "mlp")),
        "wg": P((e, d, ff), ("experts", "embed", "mlp")),
        "wo": P((e, ff, d), ("experts", "mlp", "embed")),
    }
    if cfg.num_shared_experts:
        spec["shared"] = mlp_spec(d, ff * cfg.num_shared_experts, "silu")
    return spec


def _route(params, x2d, cfg, expert_mask):
    """Router: returns (weights, idx) of shape (T, k)."""
    logits = x2d @ params["router"]                          # (T, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if expert_mask is not None:
        probs = probs * expert_mask[None, :]
    w, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w.astype(x2d.dtype), idx


def _grouped_ffn(params, x2d, w, idx, cfg, capacity_factor):
    """Sort-by-expert grouped dispatch on one token group. x2d: (T, d)."""
    t, d = x2d.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = int(math.ceil(t * k / e * capacity_factor))
    cap = max(8, ((cap + 7) // 8) * 8)

    flat_e = idx.reshape(-1)                                 # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]

    counts = jax.ops.segment_sum(jnp.ones_like(se), se, num_segments=e)
    start = jnp.cumsum(counts) - counts                      # exclusive
    pos = jnp.arange(t * k) - start[se]
    slot = jnp.where(pos < cap, se * cap + pos, e * cap)     # overflow -> sink

    xs = x2d[st]                                             # (T*k, d)
    buf = jnp.zeros((e * cap + 1, d), x2d.dtype).at[slot].set(xs)
    h = buf[: e * cap].reshape(e, cap, d)

    act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, params["wg"]))
    hid = act * jnp.einsum("ecd,edf->ecf", h, params["wi"])
    y = jnp.einsum("ecf,efd->ecd", hid, params["wo"]).reshape(e * cap, d)

    y_pad = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)], axis=0)
    contrib = y_pad[slot] * sw[:, None]
    return jax.ops.segment_sum(contrib, st, num_segments=t)


def _dense_ffn(params, x2d, w, idx, cfg):
    """Reference: all experts on all tokens, mask-combined. (T, d)."""
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    act = jax.nn.silu(jnp.einsum("td,edf->tef", x2d, params["wg"]))
    hid = act * jnp.einsum("td,edf->tef", x2d, params["wi"])
    y = jnp.einsum("tef,efd->ted", hid, params["wo"])        # (T, E, d)
    comb = jnp.zeros((x2d.shape[0], e), x2d.dtype)
    for j in range(k):                                       # k is tiny/static
        comb = comb + jax.nn.one_hot(idx[:, j], e, dtype=x2d.dtype) * w[:, j:j + 1]
    return jnp.einsum("ted,te->td", y, comb)


def moe_fwd(params, x, cfg, *,
            expert_mask: Optional[jax.Array] = None,
            mlp_mask: Optional[jax.Array] = None,
            impl: str = "grouped",
            moe_groups: int = 1,
            capacity_factor: float = 1.25):
    """x: (B, S, d) -> (B, S, d).  ``moe_groups`` must divide B*S."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    w, idx = _route(params, x2d, cfg, expert_mask)

    if impl == "dense":
        y = _dense_ffn(params, x2d, w, idx, cfg)
    else:
        g = moe_groups
        assert (b * s) % g == 0, (b, s, g)
        xg = x2d.reshape(g, (b * s) // g, d)
        wg_ = w.reshape(g, (b * s) // g, -1)
        ig = idx.reshape(g, (b * s) // g, -1)
        y = jax.vmap(lambda xx, ww, ii: _grouped_ffn(
            params, xx, ww, ii, cfg, capacity_factor))(xg, wg_, ig)
        y = y.reshape(b * s, d)

    y = y.reshape(b, s, d)
    if cfg.num_shared_experts:
        y = y + mlp_fwd(params["shared"], x, "silu", unit_mask=None)
    return y


def load_balance_loss(params, x, cfg):
    """Auxiliary load-balancing loss (Switch-style): E * sum(f_e * p_e)."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    logits = x2d @ params["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    _, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    onehot = jax.nn.one_hot(idx, cfg.num_experts).sum(axis=1)  # (T, E)
    f = onehot.mean(axis=0) / cfg.num_experts_per_tok
    p = probs.mean(axis=0)
    return cfg.num_experts * jnp.sum(f * p)
