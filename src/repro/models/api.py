"""Unified model API: family dispatch + abstract input/cache specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every model
input (weak-type-correct, shardable, no device allocation) — the dry-run
lowers against these.  Decode-cache specs are derived with ``jax.eval_shape``
over the prefill function so they always match the real cache layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import cnn, encdec, hybrid, transformer, xlstm
from repro.models import module as M


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    spec: Any
    loss_fn: Callable          # (params, batch, cfg, rt, masks) -> scalar
    prefill_fn: Optional[Callable]
    decode_fn: Optional[Callable]
    mask_schema: Dict[str, tuple]


def build(cfg: ModelConfig) -> ModelAPI:
    if cfg.family in ("dense", "moe", "vlm"):
        return ModelAPI(cfg, transformer.lm_spec(cfg), transformer.lm_loss,
                        transformer.lm_prefill, transformer.lm_decode,
                        transformer.mask_schema(cfg))
    if cfg.family == "encdec":
        return ModelAPI(cfg, encdec.encdec_spec(cfg), encdec.encdec_loss,
                        encdec.encdec_prefill, encdec.encdec_decode,
                        encdec.mask_schema(cfg))
    if cfg.family == "hybrid":
        return ModelAPI(cfg, hybrid.hybrid_spec(cfg), hybrid.hybrid_loss,
                        hybrid.hybrid_prefill, hybrid.hybrid_decode,
                        hybrid.mask_schema(cfg))
    if cfg.family == "ssm":
        return ModelAPI(cfg, xlstm.xlstm_spec(cfg), xlstm.xlstm_loss,
                        xlstm.xlstm_prefill, xlstm.xlstm_decode,
                        xlstm.xlstm_mask_schema(cfg))
    if cfg.family == "cnn":
        return ModelAPI(cfg, cnn.cnn_spec(cfg), cnn.cnn_loss, None, None,
                        cnn.cnn_mask_schema(cfg))
    raise ValueError(cfg.family)


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    return M.init_params(key, build(cfg).spec, dtype)


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    return M.abstract_params(build(cfg).spec, dtype)


def logical_axes(cfg: ModelConfig):
    return M.logical_axes(build(cfg).spec)


def default_runtime(cfg: ModelConfig, shape: Optional[ShapeConfig] = None,
                    moe_groups: int = 1) -> dict:
    """Execution knobs threaded through the model functions."""
    long_seq = shape is not None and shape.seq_len >= 8192 and \
        shape.kind != "decode"
    return {
        "attn_impl": "chunked" if long_seq else "auto",
        "moe_impl": "grouped",
        "moe_groups": moe_groups,
        # kernel-backed soft-training: "pallas" routes masked dense layers
        # and causal self-attention through the Pallas kernels (interpret
        # mode on CPU, native on TPU); "reference" is the plain-jnp path.
        # mask_block is the block-sparse skip granularity — match
        # HeliosConfig.mask_block so selection is structurally skippable.
        "kernels": "reference",
        "mask_block": 128,
        "remat": True,
        "rope": True,
        # activation sharding constraints (PartitionSpec), set by the launch
        # layer under a mesh context; None = no constraint (tests, smoke)
        "act_spec": None,
        "logits_spec": None,
        "kv_spec": None,
    }


def make_full_masks(cfg: ModelConfig, dtype=jnp.float32):
    """All-ones Helios masks (no compression) matching the mask schema."""
    return {k: jnp.ones(s, dtype) for k, s in build(cfg).mask_schema.items()}


# ---------------------------------------------------------------------------
# Abstract input specs per (family x kind)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                embed_dtype=jnp.float32) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the model inputs of one cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if cfg.family == "cnn":
        return {"images": sds((b, cfg.image_size, cfg.image_size,
                               cfg.in_channels), embed_dtype),
                "labels": sds((b,), i32)}

    if shape.kind == "decode":
        return {"token": sds((b, 1), i32)}

    if cfg.family == "encdec":
        return {"enc_embeds": sds((b, s, cfg.d_model), embed_dtype),
                "tokens": sds((b, s), i32)}
    if cfg.family == "vlm":
        n_img = cfg.num_image_tokens
        return {"tokens": sds((b, s - n_img), i32),
                "image_embeds": sds((b, n_img, cfg.d_model), embed_dtype)}
    return {"tokens": sds((b, s), i32)}


def decode_cache_specs(cfg: ModelConfig, shape: ShapeConfig, rt: dict,
                       param_dtype=jnp.float32):
    """Cache ShapeDtypeStructs for a serve_step cell, via eval_shape(prefill).

    The cache covers ``shape.seq_len`` positions (the assignment's "one new
    token with a KV cache of seq_len").
    """
    api = build(cfg)
    params = abstract_params(cfg, param_dtype)
    prompt = ShapeConfig(shape.name, "prefill", shape.seq_len,
                         shape.global_batch)
    batch = input_specs(cfg, prompt, embed_dtype=param_dtype)
    masks = {k: jax.ShapeDtypeStruct(s, jnp.float32)
             for k, s in api.mask_schema.items()}

    def run(p, b, m):
        return api.prefill_fn(p, b, cfg, rt, m)

    _, cache = jax.eval_shape(run, params, batch, masks)
    return cache
