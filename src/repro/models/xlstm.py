"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, exponential gating)
and sLSTM (scalar memory, recurrent h-feedback).

mLSTM trains with a STABILIZED CHUNKWISE algorithm (derivation in comments):
within a chunk all contributions reduce to attention-like matmuls with the
per-query stabilizer m_i = b_i + max(m0, cummax_j(i_j - b_j)); the b_i terms
cancel inside the chunk so intra scores are exp(u_j - rm_i)(k_j.q_i).
A step-by-step recurrent oracle is kept for tests.  sLSTM is inherently
sequential (h feeds back) -> lax.scan.

Helios unit: ``ssm_heads``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.module import P

D_CONV = 4


def _heads(cfg):
    d_in = 2 * cfg.d_model
    nh = cfg.num_heads
    return nh, d_in // nh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_spec(cfg):
    d = cfg.d_model
    nh, hd = _heads(cfg)
    return {
        "wx": P((d, nh, hd), ("embed", "ssm_heads", "head_dim")),
        "wz": P((d, nh, hd), ("embed", "ssm_heads", "head_dim")),
        "conv": P((D_CONV, nh, hd), ("conv_k", "ssm_heads", "head_dim"), scale=0.5),
        "wq": P((nh, hd, hd), ("ssm_heads", "head_dim", "hd2")),
        "wk": P((nh, hd, hd), ("ssm_heads", "head_dim", "hd2")),
        "wv": P((nh, hd, hd), ("ssm_heads", "head_dim", "hd2")),
        "wgi": P((nh, hd), ("ssm_heads", "head_dim"), scale=0.01),
        "bgi": P((nh,), ("ssm_heads",), init="zeros"),
        "wgf": P((nh, hd), ("ssm_heads", "head_dim"), scale=0.01),
        "bgf": P((nh,), ("ssm_heads",), init="ones"),
        "lskip": P((nh, hd), ("ssm_heads", "head_dim"), init="ones"),
        "wo": P((nh, hd, d), ("ssm_heads", "head_dim", "embed")),
    }


def _mlstm_proj(params, x, head_mask):
    xi = jnp.einsum("bsd,dhk->bshk", x, params["wx"])
    z = jnp.einsum("bsd,dhk->bshk", x, params["wz"])
    if head_mask is not None:
        xi = xi * head_mask.astype(xi.dtype)[None, None, :, None]
    pad = jnp.pad(xi, ((0, 0), (D_CONV - 1, 0), (0, 0), (0, 0)))
    co = jnp.zeros_like(xi)
    for i in range(D_CONV):
        co = co + pad[:, i:i + xi.shape[1]] * params["conv"][i][None, None]
    co = jax.nn.silu(co)
    q = jnp.einsum("bshk,hkl->bshl", co, params["wq"])
    k = jnp.einsum("bshk,hkl->bshl", co, params["wk"]) / (co.shape[-1] ** 0.5)
    v = jnp.einsum("bshk,hkl->bshl", xi, params["wv"])
    gi = jnp.einsum("bshk,hk->bsh", co, params["wgi"]) + params["bgi"]
    gf = jnp.einsum("bshk,hk->bsh", co, params["wgf"]) + params["bgf"]
    return co, z, q, k, v, gi, gf


def mlstm_chunkwise(q, k, v, gi, gf, chunk: int, state=None):
    """q,k,v: (B,S,nh,hd); gi,gf: (B,S,nh).  Returns (h, new_state).

    state = (C: (B,nh,hd,hd) value-major, n: (B,nh,hd), m: (B,nh)); the stored
    C,n are normalized by exp(m).
    """
    b, s, nh, hd = q.shape
    nc = max(1, s // chunk)
    L = s // nc
    f32 = jnp.float32

    def rs(t):
        return jnp.moveaxis(t.reshape(b, nc, L, *t.shape[2:]), 1, 0)

    qs, ks, vs = rs(q.astype(f32)), rs(k.astype(f32)), rs(v.astype(f32))
    gis, gfs = rs(gi.astype(f32)), rs(gf.astype(f32))

    if state is None:
        C0 = jnp.zeros((b, nh, hd, hd), f32)
        n0 = jnp.zeros((b, nh, hd), f32)
        m0 = jnp.full((b, nh), -1e30, f32)
    else:
        C0, n0, m0 = (state[0].astype(f32), state[1].astype(f32),
                      state[2].astype(f32))

    tril = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(carry, inp):
        C, n, m = carry                                       # normalized by e^m
        qc, kc, vc, gic, gfc = inp                            # (b,L,nh,...)
        logf = jax.nn.log_sigmoid(gfc)                        # (b,L,nh)
        bcum = jnp.cumsum(logf, axis=1)                       # inclusive
        u = gic - bcum                                        # (b,L,nh)
        rm = jnp.maximum(jax.lax.cummax(u, axis=1), m[:, None, :])  # (b,L,nh)

        s_intra = jnp.exp(u[:, None, :, :] - rm[:, :, None, :])     # (b,Lq,Lk,nh)
        s_intra = jnp.where(tril[None, :, :, None], s_intra, 0.0)
        qk = jnp.einsum("blhk,bmhk->blmh", qc, kc)            # (b,Lq,Lk,nh)
        w_carry = jnp.exp(m[:, None, :] - rm)                 # (b,L,nh)

        num = (jnp.einsum("blmh,blmh,bmhv->blhv", qk, s_intra, vc)
               + w_carry[..., None] * jnp.einsum("blhk,bhvk->blhv", qc, C))
        den_dot = (jnp.einsum("blmh,blmh->blh", qk, s_intra)
                   + w_carry * jnp.einsum("blhk,bhk->blh", qc, n))
        m_i = bcum + rm
        den = jnp.maximum(jnp.abs(den_dot), jnp.exp(-m_i))
        h = num / den[..., None]

        # end-of-chunk state
        bL = bcum[:, -1:, :]                                  # (b,1,nh)
        rmL = rm[:, -1, :]                                    # (b,nh)
        wj = jnp.exp(u - rmL[:, None, :])                     # (b,L,nh)
        C_new = (jnp.exp(m - rmL)[:, :, None, None] * C
                 + jnp.einsum("blh,blhv,blhk->bhvk", wj, vc, kc))
        n_new = (jnp.exp(m - rmL)[:, :, None] * n
                 + jnp.einsum("blh,blhk->bhk", wj, kc))
        m_new = bL[:, 0, :] + rmL
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                 (qs, ks, vs, gis, gfs))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, nh, hd).astype(q.dtype)
    return h, (C.astype(q.dtype), n.astype(q.dtype), m.astype(f32))


def mlstm_recurrent_ref(q, k, v, gi, gf, state=None):
    """Step-by-step oracle (stabilized recurrence from the paper)."""
    b, s, nh, hd = q.shape
    f32 = jnp.float32
    if state is None:
        C = jnp.zeros((b, nh, hd, hd), f32)
        n = jnp.zeros((b, nh, hd), f32)
        m = jnp.full((b, nh), -1e30, f32)
    else:
        C, n, m = [t.astype(f32) for t in state]

    def step(carry, t):
        C, n, m = carry
        logf = jax.nn.log_sigmoid(gf[:, t].astype(f32))
        m_new = jnp.maximum(logf + m, gi[:, t].astype(f32))
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(gi[:, t].astype(f32) - m_new)
        C = fp[:, :, None, None] * C + ip[:, :, None, None] * jnp.einsum(
            "bhv,bhk->bhvk", v[:, t].astype(f32), k[:, t].astype(f32))
        n = fp[:, :, None] * n + ip[:, :, None] * k[:, t].astype(f32)
        num = jnp.einsum("bhvk,bhk->bhv", C, q[:, t].astype(f32))
        dd = jnp.einsum("bhk,bhk->bh", n, q[:, t].astype(f32))
        den = jnp.maximum(jnp.abs(dd), jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    (C, n, m), hs = jax.lax.scan(step, (C, n, m), jnp.arange(s))
    return (jnp.moveaxis(hs, 0, 1).astype(q.dtype),
            (C.astype(q.dtype), n.astype(q.dtype), m))


def mlstm_fwd(params, x, cfg, *, head_mask=None, return_cache=False,
              state=None, chunk: int = 64):
    co, z, q, k, v, gi, gf = _mlstm_proj(params, x, head_mask)
    h, new_state = mlstm_chunkwise(q, k, v, gi, gf, chunk, state)
    h = h + params["lskip"][None, None] * co
    y = h * jax.nn.silu(z)
    out = jnp.einsum("bshk,hkd->bsd", y, params["wo"])
    # conv window cache for decode (last K-1 raw xi)
    if return_cache:
        xi = jnp.einsum("bsd,dhk->bshk", x, params["wx"])
        if head_mask is not None:
            xi = xi * head_mask.astype(xi.dtype)[None, None, :, None]
        conv_cache = jnp.pad(xi, ((0, 0), (D_CONV - 1, 0), (0, 0), (0, 0)))[
            :, -(D_CONV - 1):]
        return out, {"C": new_state[0], "n": new_state[1], "m": new_state[2],
                     "conv": conv_cache}
    return out


def mlstm_decode(params, x, cache, cfg, head_mask=None):
    """One-token step re-using the recurrent form."""
    xi = jnp.einsum("bsd,dhk->bshk", x, params["wx"])
    z = jnp.einsum("bsd,dhk->bshk", x, params["wz"])
    if head_mask is not None:
        xi = xi * head_mask.astype(xi.dtype)[None, None, :, None]
    window = jnp.concatenate([cache["conv"], xi], axis=1)    # (B,K,nh,hd)
    co = jax.nn.silu(jnp.einsum("bkhd,khd->bhd", window, params["conv"]))[:, None]
    q = jnp.einsum("bshk,hkl->bshl", co, params["wq"])
    k = jnp.einsum("bshk,hkl->bshl", co, params["wk"]) / (co.shape[-1] ** 0.5)
    v = jnp.einsum("bshk,hkl->bshl", xi, params["wv"])
    gi = jnp.einsum("bshk,hk->bsh", co, params["wgi"]) + params["bgi"]
    gf = jnp.einsum("bshk,hk->bsh", co, params["wgf"]) + params["bgf"]
    h, (C, n, m) = mlstm_recurrent_ref(q, k, v, gi, gf,
                                       (cache["C"], cache["n"], cache["m"]))
    h = h + params["lskip"][None, None] * co
    y = h * jax.nn.silu(z)
    out = jnp.einsum("bshk,hkd->bsd", y, params["wo"])
    return out, {"C": C, "n": n, "m": m, "conv": window[:, 1:]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_spec(cfg):
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    gates = {}
    for g in ("z", "i", "f", "o"):
        gates[f"w{g}"] = P((d, nh, hd), ("embed", "ssm_heads", "head_dim"))
        gates[f"r{g}"] = P((nh, hd, hd), ("ssm_heads", "head_dim", "hd2"),
                           scale=0.1)
        gates[f"b{g}"] = P((nh, hd), ("ssm_heads", "head_dim"),
                           init="ones" if g == "f" else "zeros")
    ff = max(1, int(4 * d / 3))
    gates.update({
        "ff_wi": P((d, ff), ("embed", "mlp")),
        "ff_wg": P((d, ff), ("embed", "mlp")),
        "ff_wo": P((ff, d), ("mlp", "embed")),
        "out_proj": P((nh, hd, d), ("ssm_heads", "head_dim", "embed")),
    })
    return gates


def slstm_scan(params, xg, state, head_mask=None):
    """xg: dict g -> (B,S,nh,hd) pre-activations (input part).

    state: (c, n, m, h) each (B,nh,hd).  Exponential-gated scalar cell.
    """
    f32 = jnp.float32

    def step(carry, t):
        c, n, m, h = carry

        def gate(g):
            rec = jnp.einsum("bhk,hkl->bhl", h, params[f"r{g}"])
            return xg[g][:, t].astype(f32) + rec + params[f"b{g}"].astype(f32)

        zt = jnp.tanh(gate("z"))
        it = gate("i")
        ft = gate("f")
        ot = jax.nn.sigmoid(gate("o"))
        m_new = jnp.maximum(ft + m, it)
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(ft + m - m_new)
        c = fp * c + ip * zt
        n = fp * n + ip
        h_new = ot * c / jnp.maximum(n, 1e-6)
        if head_mask is not None:
            h_new = h_new * head_mask.astype(h_new.dtype)[None, :, None]
        return (c, n, m_new, h_new), h_new

    s = xg["z"].shape[1]
    (c, n, m, h), hs = jax.lax.scan(step, state, jnp.arange(s))
    return jnp.moveaxis(hs, 0, 1), (c, n, m, h)


def slstm_init_state(b, nh, hd):
    z = jnp.zeros((b, nh, hd), jnp.float32)
    return (z, z, jnp.full((b, nh, hd), -1e30, jnp.float32), z)


def slstm_fwd(params, x, cfg, *, head_mask=None, return_cache=False,
              state=None):
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    xg = {g: jnp.einsum("bsd,dhk->bshk", x, params[f"w{g}"])
          for g in ("z", "i", "f", "o")}
    if state is None:
        state = slstm_init_state(x.shape[0], nh, hd)
    hs, new_state = slstm_scan(params, xg, state, head_mask)
    y = jnp.einsum("bshk,hkd->bsd", hs.astype(x.dtype), params["out_proj"])
    # gated FFN (xLSTM post-up-projection)
    ff = jax.nn.gelu(y @ params["ff_wi"]) * jax.nn.silu(y @ params["ff_wg"])
    out = y + ff @ params["ff_wo"]
    if return_cache:
        return out, {"state": new_state}
    return out


def slstm_decode(params, x, cache, cfg, head_mask=None):
    out, new = slstm_fwd(params, x, cfg, head_mask=head_mask,
                         return_cache=True, state=cache["state"])
    return out, new


# ---------------------------------------------------------------------------
# xLSTM LM assembly (family "ssm": mixed mLSTM/sLSTM stack, unrolled)
# ---------------------------------------------------------------------------

from repro.models import layers as L  # noqa: E402  (cycle-free: layers has no deps here)


def xlstm_spec(cfg):
    blocks = {}
    for i in range(cfg.num_layers):
        kind = "slstm" if i in cfg.slstm_layers else "mlstm"
        blocks[f"b{i}"] = {
            "norm": L.norm_spec(cfg.d_model, cfg.norm),
            "cell": slstm_spec(cfg) if kind == "slstm" else mlstm_spec(cfg),
        }
    return {
        "embed": L.embed_spec(cfg.padded_vocab, cfg.d_model, cfg.tie_embeddings),
        "blocks": blocks,
        "final_norm": L.norm_spec(cfg.d_model, cfg.norm),
    }


def xlstm_mask_schema(cfg):
    nh_m, _ = _heads(cfg)
    # blocks are unrolled (mixed types) -> per-block schema keys with a path
    # prefix ("b3:ssm_heads"), consumed generically by core/contribution.py.
    out = {}
    for i in range(cfg.num_layers):
        if i in cfg.slstm_layers:
            out[f"b{i}:slstm_heads"] = (1, cfg.num_heads)
        else:
            out[f"b{i}:ssm_heads"] = (1, nh_m)
    return out


def _xlstm_run(params, x, cfg, masks, mode, cache=None):
    new_cache = []
    for i in range(cfg.num_layers):
        p = params["blocks"][f"b{i}"]
        kind = "slstm" if i in cfg.slstm_layers else "mlstm"
        h = L.apply_norm(p["norm"], x, cfg.norm)
        if kind == "slstm":
            hm = None if masks is None or f"b{i}:slstm_heads" not in masks \
                else masks[f"b{i}:slstm_heads"][0]
            if mode == "train":
                y = slstm_fwd(p["cell"], h, cfg, head_mask=hm)
            elif mode == "prefill":
                y, st = slstm_fwd(p["cell"], h, cfg, head_mask=hm,
                                  return_cache=True)
                new_cache.append(st)
            else:
                y, st = slstm_decode(p["cell"], h, cache[i], cfg, head_mask=hm)
                new_cache.append(st)
        else:
            hm = None if masks is None or f"b{i}:ssm_heads" not in masks \
                else masks[f"b{i}:ssm_heads"][0]
            if mode == "train":
                y = mlstm_fwd(p["cell"], h, cfg, head_mask=hm)
            elif mode == "prefill":
                y, st = mlstm_fwd(p["cell"], h, cfg, head_mask=hm,
                                  return_cache=True)
                new_cache.append(st)
            else:
                y, st = mlstm_decode(p["cell"], h, cache[i], cfg, head_mask=hm)
                new_cache.append(st)
        x = x + y
    return x, (new_cache if mode != "train" else None)


def xlstm_loss(params, batch, cfg, rt=None, masks=None, active_mlp_idx=None):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    if rt:
        x = L.constrain(x, rt.get("act_spec"))
    x, _ = _xlstm_run(params, x, cfg, masks, "train")
    h = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], h)
    if rt:
        logits = L.constrain(logits, rt.get("logits_spec"))
    mask = jnp.ones(tokens.shape, logits.dtype).at[:, -1].set(0.0)
    return L.cross_entropy_loss(logits[:, :-1], tokens[:, 1:], mask[:, :-1])


def xlstm_prefill(params, batch, cfg, rt=None, masks=None):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    x, states = _xlstm_run(params, x, cfg, masks, "prefill")
    h = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], h[:, -1:])
    return logits[:, 0], {"states": states,
                          "pos": jnp.array(tokens.shape[1], jnp.int32)}


def xlstm_decode(params, token, cache, cfg, rt=None, masks=None):
    x = L.embed(params["embed"], token)
    x, states = _xlstm_run(params, x, cfg, masks, "decode",
                           cache=cache["states"])
    h = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], h)
    return logits[:, 0], {"states": states, "pos": cache["pos"] + 1}
