"""Core transformer layers (pure JAX, functional): norms, RoPE, GQA attention
(with dense / chunked-online-softmax / cached-decode paths), gated MLP.

Parameter layout keeps head and expert dims EXPLICIT (e.g. wq: (d, H, hd))
so that (a) the sharding rule engine can map logical axes (``heads``, ``mlp``,
``experts``) onto mesh axes and (b) Helios soft-training can mask/compact
whole units generically.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.module import P

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_spec(d: int, kind: str = "rmsnorm"):
    if kind == "rmsnorm":
        return {"scale": P((d,), ("embed",), init="ones")}
    return {"scale": P((d,), ("embed",), init="ones"),
            "bias": P((d,), ("embed",), init="zeros")}


def apply_norm(params, x, kind: str = "rmsnorm", eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10_000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                    # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA)
# ---------------------------------------------------------------------------


def attention_spec(d: int, n_heads: int, n_kv: int, head_dim: int,
                   bias: bool = False):
    spec = {
        "wq": P((d, n_heads, head_dim), ("embed", "heads", "head_dim")),
        "wk": P((d, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": P((n_heads, head_dim, d), ("heads", "head_dim", "embed")),
    }
    if bias:
        spec["bq"] = P((n_heads, head_dim), ("heads", "head_dim"), init="zeros")
        spec["bk"] = P((n_kv, head_dim), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = P((n_kv, head_dim), ("kv_heads", "head_dim"), init="zeros")
    return spec


def _project_qkv(params, x, positions, theta, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if rope:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, groups, d)).reshape(
        b, s, h * groups, d)


def dense_attention(q, k, v, *, causal: bool, q_offset: int | jax.Array = 0,
                    kv_len_mask: Optional[jax.Array] = None,
                    score_spec=None):
    """Materialized-scores attention. q:(B,Sq,H,hd) k,v:(B,Sk,KV,hd).

    ``score_spec`` pins the (B,H,Sq,Sk) score layout — decode keeps Sk
    sharded so the softmax reduces over the sharded cache sequence
    (distributed flash-decoding) instead of gathering K/V.
    """
    groups = q.shape[2] // k.shape[2]
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhk,bshk->bhqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = constrain(logits, score_spec)
    if causal:
        qpos = jnp.arange(q.shape[1]) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    if kv_len_mask is not None:                       # (B, Sk) valid-key mask
        logits = jnp.where(kv_len_mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    probs = constrain(probs, score_spec)
    return jnp.einsum("bhqs,bshk->bqhk", probs, v)


def chunked_attention(q, k, v, *, causal: bool, q_chunk: int = 512,
                      kv_chunk: int = 1024):
    """Online-softmax flash attention in pure JAX (lax.scan over KV chunks).

    O(Sq·hd) memory per query block instead of O(Sq·Sk) scores — this is the
    lowering used for the 32k prefill dry-run cells (the Pallas kernel in
    kernels/flash_attention.py is the TPU-native version of this same
    schedule; its ref.py oracle is dense_attention above).  The named_scope
    lets the roofline analysis attribute this scope's HBM traffic (the score
    blocks the Pallas kernel keeps in VMEM) — parallel/hlo_cost.pattern_bytes.
    """
    with jax.named_scope("chunked_attention"):
        return _chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                                  kv_chunk=kv_chunk)


def _chunked_attention(q, k, v, *, causal: bool, q_chunk: int,
                       kv_chunk: int):
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    groups = h // k.shape[2]
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = hd ** -0.5
    n_q = max(1, sq // q_chunk)
    q_chunk = sq // n_q
    n_kv = max(1, sk // kv_chunk)
    kv_chunk = sk // n_kv

    qr = q.reshape(b, n_q, q_chunk, h, hd)
    kr = k.reshape(b, n_kv, kv_chunk, h, hd)
    vr = v.reshape(b, n_kv, kv_chunk, h, hd)

    def per_qchunk(qi, qblk):
        # qblk: (b, q_chunk, h, hd)
        def body(carry, inputs):
            acc, m, denom = carry
            ki, kblk, vblk = inputs
            # f32 accumulation WITHOUT materializing f32 copies of K/V
            logits = jnp.einsum("bqhk,bshk->bhqs", qblk, kblk,
                                preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = qpos[:, None] >= kpos[None, :]
                logits = jnp.where(mask[None, None], logits, -1e30)
            blk_max = jnp.max(logits, axis=-1)                    # (b,h,q)
            new_m = jnp.maximum(m, blk_max)
            correction = jnp.exp(m - new_m)
            p = jnp.exp(logits - new_m[..., None])                # (b,h,q,s)
            denom = denom * correction + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqs,bshk->bqhk", p.astype(vblk.dtype), vblk)
            acc = acc * correction.transpose(0, 2, 1)[..., None] + pv
            return (acc, new_m, denom), None

        acc0 = jnp.zeros((b, q_chunk, h, hd), jnp.float32)
        m0 = jnp.full((b, h, q_chunk), -1e30, jnp.float32)
        d0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        ks = jnp.arange(n_kv)
        (acc, m, denom), _ = jax.lax.scan(
            body, (acc0, m0, d0),
            (ks, jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)))
        out = acc / jnp.maximum(denom, 1e-30).transpose(0, 2, 1)[..., None]
        return out.astype(q.dtype)

    outs = jax.lax.map(lambda args: per_qchunk(args[0], args[1]),
                       (jnp.arange(n_q), jnp.moveaxis(qr, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)


def attend(q, k, v, *, causal: bool, impl: str = "auto",
           kv_len_mask: Optional[jax.Array] = None, q_offset=0):
    """Dispatch: dense for short, chunked for long sequences, and
    ``impl="pallas"`` for the kernel-backed training path (Pallas flash
    attention with a recompute VJP).  The kernel handles the full-sequence
    causal self-attention case; anything else (decode with a valid-key
    mask, non-zero query offsets, cross-length) falls back to "auto"."""
    if impl == "pallas":
        # long sequences keep the chunked lowering even under kernels=
        # "pallas": the flash kernel's recompute VJP materializes O(S²)
        # scores in the backward, which is what chunked exists to avoid
        # (same 4096 threshold as the "auto" resolution below)
        if causal and kv_len_mask is None and q.shape[1] == k.shape[1] \
                and q.shape[1] < 4096 \
                and isinstance(q_offset, int) and q_offset == 0:
            from repro.kernels import ops
            groups = q.shape[2] // k.shape[2]
            kf = _repeat_kv(k, groups)
            vf = _repeat_kv(v, groups)
            out = ops.flash_attention(q.transpose(0, 2, 1, 3),
                                      kf.transpose(0, 2, 1, 3),
                                      vf.transpose(0, 2, 1, 3), causal=True)
            return out.transpose(0, 2, 1, 3)
        impl = "auto"
    if impl == "auto":
        impl = "chunked" if (q.shape[1] >= 4096 and q.shape[1] == k.shape[1]
                             and kv_len_mask is None) else "dense"
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal)
    return dense_attention(q, k, v, causal=causal, q_offset=q_offset,
                           kv_len_mask=kv_len_mask)


def attention_fwd(params, x, positions, *, causal=True, theta=10_000.0,
                  impl="auto", rope=True, head_mask: Optional[jax.Array] = None,
                  kv_spec=None):
    """Full self-attention over x: (B, S, d)."""
    q, k, v = _project_qkv(params, x, positions, theta, rope=rope)
    if head_mask is not None:                     # Helios: mask whole Q heads
        q = q * head_mask.astype(q.dtype)[None, None, :, None]
    # pin K/V layout BEFORE the chunked loop so GSPMD gathers them once per
    # layer instead of once per query chunk (EXPERIMENTS.md §Perf, cell A)
    k, v = constrain(k, kv_spec), constrain(v, kv_spec)
    out = attend(q, k, v, causal=causal, impl=impl)
    return jnp.einsum("bqhk,hkd->bqd", out, params["wo"])


def attention_prefill(params, x, positions, *, theta=10_000.0, impl="auto",
                      rope=True, head_mask=None, kv_spec=None):
    """Self-attention that also returns the KV cache (pre-RoPE-applied K)."""
    q, k, v = _project_qkv(params, x, positions, theta, rope=rope)
    if head_mask is not None:
        q = q * head_mask.astype(q.dtype)[None, None, :, None]
    k, v = constrain(k, kv_spec), constrain(v, kv_spec)
    out = attend(q, k, v, causal=True, impl=impl)
    return jnp.einsum("bqhk,hkd->bqd", out, params["wo"]), {"k": k, "v": v}


def attention_decode(params, x, cache, pos, *, theta=10_000.0, rope=True,
                     head_mask=None, kv_spec=None):
    """One-token decode: x (B, 1, d); cache {"k","v"}: (B, S_max, KV, hd).

    The new token is written at position ``pos`` (scalar int32) and attention
    runs over positions <= pos.  ``kv_spec`` pins the updated cache to its
    sharded layout (seq over "model" for small-GQA archs) so the attention
    reduces over the SHARDED sequence dim — distributed flash-decoding —
    instead of all-gathering the cache every step (EXPERIMENTS.md §Perf B).
    """
    positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, positions, theta, rope=rope)
    if head_mask is not None:
        q = q * head_mask.astype(q.dtype)[None, None, :, None]
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(
        cache["k"].dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(
        cache["v"].dtype), pos, axis=1)
    k, v = constrain(k, kv_spec), constrain(v, kv_spec)
    score_spec = None
    if kv_spec is not None and len(kv_spec) >= 2 and kv_spec[1] is not None:
        # scores (B,H,1,S): keep S on the cache's mesh axis
        from jax.sharding import PartitionSpec as _P
        score_spec = _P(kv_spec[0], None, None, kv_spec[1])
    valid = (jnp.arange(k.shape[1]) <= pos)[None, :]
    valid = jnp.broadcast_to(valid, (x.shape[0], k.shape[1]))
    out = dense_attention(q, k, v, causal=False, kv_len_mask=valid,
                          score_spec=score_spec)
    return jnp.einsum("bqhk,hkd->bqd", out, params["wo"]), {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU) with optional Helios compaction
# ---------------------------------------------------------------------------


def mlp_spec(d: int, ff: int, activation: str = "silu"):
    if activation == "silu":
        return {
            "wi": P((d, ff), ("embed", "mlp")),
            "wg": P((d, ff), ("embed", "mlp")),
            "wo": P((ff, d), ("mlp", "embed")),
        }
    return {
        "wi": P((d, ff), ("embed", "mlp")),
        "wo": P((ff, d), ("mlp", "embed")),
    }


def mlp_fwd(params, x, activation: str = "silu",
            unit_mask: Optional[jax.Array] = None,
            active_idx: Optional[jax.Array] = None,
            kernels: Optional[str] = None, mask_block: int = 128):
    """Gated MLP.

    Helios hooks:
      * ``unit_mask`` (masked mode): float 0/1 over d_ff — paper-faithful
        semantics; with ``kernels="pallas"`` the masked matmuls run on the
        block-sparse Pallas pair (dead column blocks skipped in forward AND
        backward, masked-unit grads exactly zero) so the volume fraction P
        becomes real compute savings.  ``mask_block`` is the skip
        granularity (match HeliosConfig.mask_block for structural skipping).
      * ``active_idx`` (compact mode): int32 (k,) of active hidden units —
        weights are GATHERED to (d, k) so the compiled matmuls shrink by
        k/d_ff.  TPU-native soft-training (DESIGN.md §2).
    """
    wi, wo = params["wi"], params["wo"]
    wg = params.get("wg")
    if active_idx is not None:
        wi = jnp.take(wi, active_idx, axis=1)
        wo = jnp.take(wo, active_idx, axis=0)
        if wg is not None:
            wg = jnp.take(wg, active_idx, axis=1)
    if kernels == "pallas" and unit_mask is not None and active_idx is None:
        from repro.kernels import ops
        hi = ops.masked_dense(x, wi, unit_mask, impl="pallas",
                              block_n=mask_block)
        if activation == "silu":
            hg = ops.masked_dense(x, wg, unit_mask, impl="pallas",
                                  block_n=mask_block)
            h = jax.nn.silu(hg) * hi
        else:
            h = jax.nn.gelu(hi)
        return ops.masked_contract(h, wo, unit_mask, impl="pallas",
                                   block_n=mask_block)
    h = x @ wi
    if activation == "silu":
        h = jax.nn.silu(x @ wg) * h
    else:
        h = jax.nn.gelu(h)
    if unit_mask is not None and active_idx is None:
        h = h * unit_mask.astype(h.dtype)[None, None, :]
    return h @ wo


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_spec(vocab: int, d: int, tie: bool):
    spec = {"embedding": P((vocab, d), ("vocab", "embed"), init="embed",
                           scale=0.02)}
    if not tie:
        spec["unembed"] = P((d, vocab), ("embed", "vocab"), init="embed",
                            scale=0.02)
    return spec


def embed(params, tokens):
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params, x):
    if "unembed" in params:
        return x @ params["unembed"]
    return x @ params["embedding"].T


def constrain(x, spec):
    """with_sharding_constraint when a PartitionSpec is provided (the launch
    layer threads specs through rt; tests/smoke paths pass None)."""
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def cross_entropy_loss(logits, targets, mask=None):
    """Mean next-token CE.  logits: (B,S,V); targets: (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
