"""Minimal functional module system: parameter *specs* as single source of truth.

A model is described by a nested dict of :class:`P` leaves.  From that one
spec we derive:

* ``init_params``     — concrete arrays (CPU training, smoke tests)
* ``abstract_params`` — ``jax.ShapeDtypeStruct`` tree (dry-run: no allocation)
* ``logical_axes``    — tree of logical-axis-name tuples, consumed by both the
  sharding rule engine (parallel/sharding.py) and Helios masking/contribution
  (core/masking.py) — masks act on the ``mlp`` / ``heads`` / ``experts`` /
  ``ssm_heads`` / ``filters`` axes.

``stack(spec, n)`` prepends a ``layers`` axis to every leaf for
scan-over-layers assembly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """One parameter leaf: shape + logical axes + initializer."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev override (normal/embed)
    dtype: Any = None              # dtype override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec_leaf(x) -> bool:
    return isinstance(x, P)


def _map_spec(fn, spec, path=()):
    if isinstance(spec, dict):
        return {k: _map_spec(fn, v, path + (k,)) for k, v in spec.items()}
    return fn(path, spec)


def _fan_in(p: P) -> int:
    """Fan-in heuristic: product of all dims except the last."""
    if len(p.shape) <= 1:
        return max(1, p.shape[0] if p.shape else 1)
    n = 1
    for s in p.shape[:-1]:
        n *= s
    return max(1, n)


def _path_key(root: jax.Array, path: Tuple[str, ...]) -> jax.Array:
    """Deterministic per-leaf key derived from the path string."""
    h = np.uint32(2166136261)
    for part in "/".join(path).encode():
        h = np.uint32((int(h) ^ part) * 16777619 & 0xFFFFFFFF)
    return jax.random.fold_in(root, int(h))


def init_params(key: jax.Array, spec, dtype=jnp.float32):
    def make(path, p: P):
        dt = p.dtype or dtype
        if p.init == "zeros":
            return jnp.zeros(p.shape, dt)
        if p.init == "ones":
            return jnp.ones(p.shape, dt)
        k = _path_key(key, path)
        if p.init == "embed":
            scale = p.scale if p.scale is not None else 1.0
            return (jax.random.normal(k, p.shape) * scale).astype(dt)
        scale = p.scale if p.scale is not None else 1.0 / np.sqrt(_fan_in(p))
        return (jax.random.normal(k, p.shape) * scale).astype(dt)

    return _map_spec(make, spec)


def abstract_params(spec, dtype=jnp.float32):
    return _map_spec(
        lambda _, p: jax.ShapeDtypeStruct(p.shape, p.dtype or dtype), spec)


def logical_axes(spec):
    return _map_spec(lambda _, p: p.axes, spec)


def stack(spec, n: int, axis_name: str = "layers"):
    """Stack a per-layer spec n times (scan-over-layers parameter layout)."""
    return _map_spec(
        lambda _, p: dataclasses.replace(
            p, shape=(n,) + p.shape, axes=(axis_name,) + p.axes), spec)


def param_count(spec) -> int:
    total = 0
    for leaf in jax.tree.leaves(spec, is_leaf=is_spec_leaf):
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
    return total


def tree_paths(tree, is_leaf=None):
    """List of ('a/b/c', leaf) pairs in deterministic order."""
    out = []

    def rec(node, path):
        if isinstance(node, dict) and (is_leaf is None or not is_leaf(node)):
            for k in sorted(node):
                rec(node[k], path + (k,))
        else:
            out.append(("/".join(path), node))

    rec(tree, ())
    return out
