"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Prefill/train use the non-absorbed form (materialize per-head K/V from the
latent) with chunked attention; decode uses the ABSORBED form: scores are
computed directly against the cached latent ``c_kv`` (B,S,kv_rank) and the
shared RoPE key (B,S,rope_dim), so the KV cache is rank+rope_dim wide instead
of 2*H*hd — the whole point of MLA for 32k/500k caches.

The latent bottleneck is shared across heads and is therefore NOT a Helios
maskable unit; ``heads`` is (head_mask hook below).  See DESIGN.md §4.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.module import P
from repro.models.layers import apply_norm, apply_rope, attend, norm_spec


def mla_spec(cfg):
    d = cfg.d_model
    h = cfg.num_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": P((d, qr), ("embed", "q_lora")),
        "q_norm": norm_spec(qr, "rmsnorm"),
        "wq_b": P((qr, h, nope + rope), ("q_lora", "heads", "head_dim")),
        "wkv_a": P((d, kr + rope), ("embed", "kv_lora")),
        "kv_norm": norm_spec(kr, "rmsnorm"),
        "wk_b": P((kr, h, nope), ("kv_lora", "heads", "head_dim")),
        "wv_b": P((kr, h, vd), ("kv_lora", "heads", "head_dim")),
        "wo": P((h, vd, d), ("heads", "head_dim", "embed")),
    }


def _latent(params, x, positions, cfg):
    """Shared latent pipeline: returns (q, c_kv, k_rope)."""
    kr, rope = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    nope = cfg.qk_nope_head_dim
    q_lat = apply_norm(params["q_norm"], x @ params["wq_a"])
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["wq_b"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ params["wkv_a"]
    c_kv = apply_norm(params["kv_norm"], kv[..., :kr])
    k_rope = kv[..., kr:][:, :, None, :]                     # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_fwd(params, x, positions, cfg, *, impl="auto",
            head_mask: Optional[jax.Array] = None, return_cache=False):
    """Train/prefill path (non-absorbed)."""
    nope, vd = cfg.qk_nope_head_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _latent(params, x, positions, cfg)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"])
    v = jnp.einsum("bsr,rhv->bshv", c_kv, params["wv_b"])
    h = cfg.num_heads
    k_rope_b = jnp.broadcast_to(k_rope, k_rope.shape[:2] + (h, k_rope.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    if head_mask is not None:
        q = q * head_mask.astype(q.dtype)[None, None, :, None]
    # pad v so attend() can run one fused pass; slice the value dims back out
    if v.shape[-1] != q.shape[-1]:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, q.shape[-1] - v.shape[-1])))
    out = attend(q, k, v, causal=True, impl=impl)[..., :vd]
    y = jnp.einsum("bqhv,hvd->bqd", out, params["wo"])
    if return_cache:
        return y, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}
    return y


def mla_decode(params, x, cache, pos, cfg, head_mask=None):
    """Absorbed one-token decode against the latent cache.

    cache: {"c_kv": (B,S,kv_rank), "k_rope": (B,S,rope_dim)}.
    """
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
    q_nope, q_rope, c_new, kr_new = _latent(params, x, positions, cfg)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new[:, :, 0, :].astype(cache["k_rope"].dtype),
        pos, axis=1)

    # absorb W_uk into the query: score_nope = (q_nope @ W_uk^T) . c_kv
    q_eff = jnp.einsum("bqhk,rhk->bqhr", q_nope, params["wk_b"])
    if head_mask is not None:
        q_eff = q_eff * head_mask.astype(q_eff.dtype)[None, None, :, None]
        q_rope = q_rope * head_mask.astype(q_rope.dtype)[None, None, :, None]
    scale = (nope + rope) ** -0.5
    logits = (jnp.einsum("bqhr,bsr->bhqs", q_eff, c_kv)
              + jnp.einsum("bqhk,bsk->bhqs", q_rope, k_rope)).astype(
                  jnp.float32) * scale
    valid = (jnp.arange(c_kv.shape[1]) <= pos)[None, None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", probs, c_kv)        # attend in latent
    out = jnp.einsum("bqhr,rhv->bqhv", o_lat, params["wv_b"])
    y = jnp.einsum("bqhv,hvd->bqd", out, params["wo"])
    return y, {"c_kv": c_kv, "k_rope": k_rope}
