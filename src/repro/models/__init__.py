from repro.models.api import (ModelAPI, abstract_params, build,
                              decode_cache_specs, default_runtime,
                              init_params, input_specs, logical_axes,
                              make_full_masks)

__all__ = ["ModelAPI", "build", "init_params", "abstract_params",
           "logical_axes", "input_specs", "decode_cache_specs",
           "default_runtime", "make_full_masks"]
