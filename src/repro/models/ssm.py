"""Mamba2 (SSD) block in chunked, MXU-friendly matmul form.

Training/prefill use the chunked SSD algorithm (intra-chunk attention-like
matmuls + inter-chunk state scan) — O(S·L) compute with chunk length L, all
matmuls, which is the TPU-native expression of the selective scan (see
kernels/ssd_scan.py for the Pallas version of the intra-chunk block).
Decode is the O(1) recurrent step against the (heads, head_dim, state) cache.

Helios unit: ``ssm_heads`` — state dims within a head are coupled, heads are
independent (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.module import P

D_CONV = 4  # depthwise causal conv kernel width


def mamba2_spec(cfg):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    nh = d_in // cfg.ssm_head_dim
    hd = cfg.ssm_head_dim
    ds = cfg.ssm_state
    return {
        "wx": P((d, nh, hd), ("embed", "ssm_heads", "head_dim")),
        "wz": P((d, nh, hd), ("embed", "ssm_heads", "head_dim")),
        "wB": P((d, ds), ("embed", "ssm_state")),
        "wC": P((d, ds), ("embed", "ssm_state")),
        "wdt": P((d, nh), ("embed", "ssm_heads")),
        "dt_bias": P((nh,), ("ssm_heads",), init="zeros"),
        "A_log": P((nh,), ("ssm_heads",), init="zeros"),
        "D": P((nh,), ("ssm_heads",), init="ones"),
        "conv": P((D_CONV, nh, hd), ("conv_k", "ssm_heads", "head_dim"),
                  scale=0.5),
        "wo": P((nh, hd, d), ("ssm_heads", "head_dim", "embed")),
    }


def _proj(params, x, head_mask):
    """Shared projections. x: (B,S,d)."""
    xh = jnp.einsum("bsd,dhk->bshk", x, params["wx"])
    z = jnp.einsum("bsd,dhk->bshk", x, params["wz"])
    Bm = x @ params["wB"]                                    # (B,S,ds)
    Cm = x @ params["wC"]
    dt = jax.nn.softplus(x @ params["wdt"] + params["dt_bias"])  # (B,S,nh)
    if head_mask is not None:
        xh = xh * head_mask.astype(xh.dtype)[None, None, :, None]
        dt = dt * head_mask.astype(dt.dtype)[None, None, :]
    return xh, z, Bm, Cm, dt


def _causal_conv(xh, kernel):
    """Depthwise causal conv over time. xh: (B,S,nh,hd); kernel: (K,nh,hd)."""
    pad = jnp.pad(xh, ((0, 0), (D_CONV - 1, 0), (0, 0), (0, 0)))
    out = jnp.zeros_like(xh)
    for i in range(D_CONV):                                  # K=4, unrolled
        out = out + pad[:, i:i + xh.shape[1]] * kernel[i][None, None]
    return jax.nn.silu(out)


def ssd_chunked(xh, Bm, Cm, dt, A, chunk: int, h0=None):
    """Chunked SSD. xh:(B,S,nh,hd) Bm,Cm:(B,S,ds) dt:(B,S,nh) A:(nh,)<0.

    Returns (y, h_final) with h_final: (B,nh,hd,ds).
    """
    b, s, nh, hd = xh.shape
    ds = Bm.shape[-1]
    nc = max(1, s // chunk)
    L = s // nc
    f32 = jnp.float32

    xr = xh.reshape(b, nc, L, nh, hd)
    Br = Bm.reshape(b, nc, L, ds).astype(f32)
    Cr = Cm.reshape(b, nc, L, ds).astype(f32)
    dtr = dt.reshape(b, nc, L, nh).astype(f32)
    a = dtr * A[None, None, None, :]                         # (b,nc,L,nh) <= 0
    cum = jnp.cumsum(a, axis=2)                              # inclusive
    dtx = (dtr[..., None] * xr.astype(f32))                  # (b,nc,L,nh,hd)

    # ---- intra-chunk (attention-like, per head) ----
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (b,nc,L,L,nh)
    tril = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(tril[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bnli,bnmi->bnlm", Cr, Br)               # (b,nc,L,L)
    y_diag = jnp.einsum("bnlm,bnlmh,bnmhp->bnlhp", cb, decay, dtx)

    # ---- chunk states ----
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)             # (b,nc,L,nh)
    states = jnp.einsum("bnlh,bnlhp,bnli->bnhpi", decay_out, dtx, Br)

    # ---- inter-chunk recurrence over nc (small) ----
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (b,nc,nh)

    def body(h, inp):
        st, dec = inp
        h_new = h * dec[:, :, None, None] + st
        return h_new, h

    init = jnp.zeros((b, nh, hd, ds), f32) if h0 is None else h0.astype(f32)
    h_final, h_starts = jax.lax.scan(
        body, init, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_starts = jnp.moveaxis(h_starts, 0, 1)                  # (b,nc,nh,hd,ds)

    # ---- inter contribution ----
    y_off = jnp.einsum("bnli,bnhpi,bnlh->bnlhp", Cr, h_starts, jnp.exp(cum))
    y = (y_diag + y_off).reshape(b, s, nh, hd).astype(xh.dtype)
    return y, h_final.astype(xh.dtype)


def ssd_recurrent_ref(xh, Bm, Cm, dt, A, h0=None):
    """Step-by-step oracle for tests."""
    b, s, nh, hd = xh.shape
    ds = Bm.shape[-1]
    h = jnp.zeros((b, nh, hd, ds), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        a = jnp.exp(dt[:, t].astype(jnp.float32) * A)        # (b,nh)
        upd = (dt[:, t, :, None, None] * xh[:, t, :, :, None].astype(jnp.float32)
               * Bm[:, t, None, None, :].astype(jnp.float32))
        h = h * a[:, :, None, None] + upd
        y = jnp.einsum("bhpi,bi->bhp", h, Cm[:, t].astype(jnp.float32))
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1).astype(xh.dtype), h.astype(xh.dtype)


def mamba2_fwd(params, x, cfg, *, head_mask: Optional[jax.Array] = None,
               return_cache: bool = False, impl: str = "chunked"):
    """Full block: (B,S,d) -> (B,S,d)."""
    xh_raw, z, Bm, Cm, dt = _proj(params, x, head_mask)
    xh = _causal_conv(xh_raw, params["conv"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    if impl == "recurrent":
        y, h = ssd_recurrent_ref(xh, Bm, Cm, dt, A)
    else:
        y, h = ssd_chunked(xh, Bm, Cm, dt, A, cfg.ssm_chunk)
    y = y + params["D"][None, None, :, None] * xh
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bshk,hkd->bsd", y, params["wo"])
    if return_cache:
        # cache the last K-1 RAW (pre-conv) inputs; decode re-applies the kernel
        conv_state = jnp.pad(
            xh_raw, ((0, 0), (D_CONV - 1, 0), (0, 0), (0, 0)))[:, -(D_CONV - 1):]
        return out, {"h": h, "conv": conv_state}
    return out


def mamba2_decode(params, x, cache, cfg, head_mask=None):
    """One-token step. x: (B,1,d); cache {"h": (B,nh,hd,ds), "conv": (B,K-1,nh,hd)}."""
    xh, z, Bm, Cm, dt = _proj(params, x, head_mask)          # (B,1,...)
    window = jnp.concatenate([cache["conv"], xh], axis=1)    # (B,K,nh,hd)
    conv_out = jnp.einsum("bkhd,khd->bhd", window, params["conv"])[:, None]
    xh_c = jax.nn.silu(conv_out)                             # (B,1,nh,hd)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[:, 0].astype(jnp.float32) * A)            # (B,nh)
    upd = (dt[:, 0, :, None, None] * xh_c[:, 0, :, :, None].astype(jnp.float32)
           * Bm[:, 0, None, None, :].astype(jnp.float32))
    h = cache["h"].astype(jnp.float32) * a[:, :, None, None] + upd
    y = jnp.einsum("bhpi,bi->bhp", h, Cm[:, 0].astype(jnp.float32))[:, None]
    y = y.astype(x.dtype) + params["D"][None, None, :, None] * xh_c
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bshk,hkd->bsd", y, params["wo"])
    return out, {"h": h.astype(cache["h"].dtype), "conv": window[:, 1:]}
