"""Encoder-decoder assembly (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, d_model).  Decoder = causal self-attn
+ cross-attn + FFN.  Both stacks scan over stacked layer params.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.module import stack


def _enc_block_spec(cfg):
    return {
        "attn_norm": L.norm_spec(cfg.d_model, cfg.norm),
        "attn": L.attention_spec(cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                                 cfg.resolved_head_dim, cfg.qkv_bias),
        "mlp_norm": L.norm_spec(cfg.d_model, cfg.norm),
        "mlp": L.mlp_spec(cfg.d_model, cfg.d_ff, cfg.activation),
    }


def _dec_block_spec(cfg):
    spec = _enc_block_spec(cfg)
    spec["cross_norm"] = L.norm_spec(cfg.d_model, cfg.norm)
    spec["cross"] = L.attention_spec(cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.resolved_head_dim,
                                     cfg.qkv_bias)
    return spec


def encdec_spec(cfg: ModelConfig):
    return {
        "embed": L.embed_spec(cfg.padded_vocab, cfg.d_model, cfg.tie_embeddings),
        "enc_blocks": stack(_enc_block_spec(cfg), cfg.enc_layers),
        "dec_blocks": stack(_dec_block_spec(cfg), cfg.dec_layers),
        "enc_norm": L.norm_spec(cfg.d_model, cfg.norm),
        "final_norm": L.norm_spec(cfg.d_model, cfg.norm),
    }


def mask_schema(cfg: ModelConfig) -> Dict[str, tuple]:
    return {
        "enc_heads": (cfg.enc_layers, cfg.num_heads),
        "enc_mlp": (cfg.enc_layers, cfg.d_ff),
        "heads": (cfg.dec_layers, cfg.num_heads),
        "cross_heads": (cfg.dec_layers, cfg.num_heads),
        "mlp": (cfg.dec_layers, cfg.d_ff),
    }


def _cross_attend(p, h, enc_out, head_mask=None, cross_kv=None):
    """Cross attention: q from decoder h, k/v from encoder output."""
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    if cross_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
    else:
        k, v = cross_kv["k"], cross_kv["v"]
    if head_mask is not None:
        q = q * head_mask.astype(q.dtype)[None, None, :, None]
    out = L.attend(q, k, v, causal=False, impl="auto")
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"]), {"k": k, "v": v}


def _encode(params, enc_embeds, cfg, rt, masks=None):
    x = enc_embeds
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(carry, inp):
        p, m = inp["p"], inp.get("m", {})
        h = L.apply_norm(p["attn_norm"], carry, cfg.norm)
        a = L.attention_fwd(p["attn"], h, positions, causal=False,
                            theta=cfg.rope_theta, impl=rt["attn_impl"],
                            head_mask=m.get("enc_heads"))
        x2 = carry + a
        h2 = L.apply_norm(p["mlp_norm"], x2, cfg.norm)
        y = L.mlp_fwd(p["mlp"], h2, cfg.activation, unit_mask=m.get("enc_mlp"))
        return x2 + y, None

    xs = {"p": params["enc_blocks"]}
    if masks:
        sl = {k: masks[k] for k in ("enc_heads", "enc_mlp") if k in masks}
        if sl:
            xs["m"] = sl
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, xs)
    return L.apply_norm(params["enc_norm"], x, cfg.norm)


def _dec_xs(params, masks):
    xs = {"p": params["dec_blocks"]}
    if masks:
        sl = {k: masks[k] for k in ("heads", "cross_heads", "mlp") if k in masks}
        if sl:
            xs["m"] = sl
    return xs


def encdec_loss(params, batch, cfg: ModelConfig, rt, masks=None,
                active_mlp_idx=None):
    enc_out = _encode(params, batch["enc_embeds"], cfg, rt, masks)
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(carry, inp):
        p, m = inp["p"], inp.get("m", {})
        h = L.apply_norm(p["attn_norm"], carry, cfg.norm)
        a = L.attention_fwd(p["attn"], h, positions, causal=True,
                            theta=cfg.rope_theta, impl=rt["attn_impl"],
                            head_mask=m.get("heads"))
        x2 = carry + a
        h2 = L.apply_norm(p["cross_norm"], x2, cfg.norm)
        c, _ = _cross_attend(p["cross"], h2, enc_out, m.get("cross_heads"))
        x3 = x2 + c
        h3 = L.apply_norm(p["mlp_norm"], x3, cfg.norm)
        y = L.mlp_fwd(p["mlp"], h3, cfg.activation, unit_mask=m.get("mlp"))
        return x3 + y, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, _dec_xs(params, masks))
    h = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.constrain(L.unembed(params["embed"], h),
                         rt.get("logits_spec"))
    mask = jnp.ones(tokens.shape, logits.dtype).at[:, -1].set(0.0)
    return L.cross_entropy_loss(logits[:, :-1], tokens[:, 1:], mask[:, :-1])


def encdec_prefill(params, batch, cfg: ModelConfig, rt, masks=None):
    """Encode + run decoder over the prompt; build self+cross caches."""
    enc_out = _encode(params, batch["enc_embeds"], cfg, rt, masks)
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(carry, inp):
        p, m = inp["p"], inp.get("m", {})
        h = L.apply_norm(p["attn_norm"], carry, cfg.norm)
        a, self_kv = L.attention_prefill(p["attn"], h, positions,
                                         theta=cfg.rope_theta,
                                         impl=rt["attn_impl"],
                                         head_mask=m.get("heads"))
        x2 = carry + a
        h2 = L.apply_norm(p["cross_norm"], x2, cfg.norm)
        c, cross_kv = _cross_attend(p["cross"], h2, enc_out,
                                    m.get("cross_heads"))
        x3 = x2 + c
        h3 = L.apply_norm(p["mlp_norm"], x3, cfg.norm)
        y = L.mlp_fwd(p["mlp"], h3, cfg.activation, unit_mask=m.get("mlp"))
        return x3 + y, {"self": self_kv, "cross": cross_kv}

    x, kv = jax.lax.scan(body, x, _dec_xs(params, masks))
    h = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], h[:, -1:])
    return logits[:, 0], {"kv": kv, "pos": jnp.array(s, jnp.int32)}


def encdec_decode(params, token, cache, cfg: ModelConfig, rt, masks=None):
    x = L.embed(params["embed"], token)
    pos = cache["pos"]

    def body(carry, inp):
        p, kv, m = inp["p"], inp["kv"], inp.get("m", {})
        h = L.apply_norm(p["attn_norm"], carry, cfg.norm)
        a, self_kv = L.attention_decode(p["attn"], h, kv["self"], pos,
                                        theta=cfg.rope_theta,
                                        head_mask=m.get("heads"))
        x2 = carry + a
        h2 = L.apply_norm(p["cross_norm"], x2, cfg.norm)
        c, _ = _cross_attend(p["cross"], h2, None, m.get("cross_heads"),
                             cross_kv=kv["cross"])
        x3 = x2 + c
        h3 = L.apply_norm(p["mlp_norm"], x3, cfg.norm)
        y = L.mlp_fwd(p["mlp"], h3, cfg.activation, unit_mask=m.get("mlp"))
        return x3 + y, {"self": self_kv, "cross": kv["cross"]}

    xs = _dec_xs(params, masks)
    xs["kv"] = cache["kv"]
    x, kv_new = jax.lax.scan(body, x, xs)
    h = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], h)
    return logits[:, 0], {"kv": kv_new, "pos": pos + 1}
