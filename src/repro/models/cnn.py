"""The paper's CNN testbed: LeNet-5, CIFAR-scale AlexNet, ResNet-18.

These run the faithful FL reproduction (Fig. 5-7).  BatchNorm is replaced by
GroupNorm — standard practice in FL where per-client batch statistics diverge
(noted in DESIGN.md §7).  Helios maskable unit: conv ``filters`` and dense
hidden units; masks are applied to layer OUTPUT channels so masked filters
receive zero gradients (soft-training semantics).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.module import P


def _conv(name, kh, kw, cin, cout):
    return {f"{name}_w": P((kh, kw, cin, cout), (None, None, "embed", "filters")),
            f"{name}_b": P((cout,), ("filters",), init="zeros")}


def _dense(name, din, dout, unit_axis="filters"):
    return {f"{name}_w": P((din, dout), ("embed", unit_axis)),
            f"{name}_b": P((dout,), (unit_axis,), init="zeros")}


def conv2d(x, w, b, stride=1, padding="SAME"):
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def group_norm(x, groups=8, eps=1e-5):
    n, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(n, h, w, g, c // g)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    return ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(n, h, w, c)


def avg_pool(x, k=2):
    return jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, k, k, 1),
                                 (1, k, k, 1), "VALID") / (k * k)


def max_pool(x, k=2, s=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, k, k, 1),
                                 (1, s, s, 1), "VALID")


def _m(masks, key):
    if masks is None or key not in masks:
        return None
    v = masks[key]
    return v[0] if v.ndim == 2 else v


def _apply(x, mask):
    return x if mask is None else x * mask


def _fc(params, x, name, masks, kernels, mask_block, act=jnp.tanh):
    """One maskable dense layer: act(x @ W + b) · mask.

    With ``kernels="pallas"`` the matmul runs on the block-sparse Pallas
    kernel — x @ (W·mask) with dead column blocks skipped in forward and
    backward; the output-channel mask still multiplies the activation, so
    the numerics match the reference path exactly (masked units are zero
    either way, and their W/b gradients are exactly zero in both)."""
    m = _m(masks, name)
    w, b = params[f"{name}_w"], params[f"{name}_b"]
    if kernels == "pallas" and m is not None:
        from repro.kernels import ops
        z = ops.masked_dense(x, w, m, impl="pallas", block_n=mask_block)
    else:
        z = x @ w
    return _apply(act(z + b), m)


# ---------------------------------------------------------------------------
# LeNet-5
# ---------------------------------------------------------------------------


def lenet_spec(cfg: ModelConfig):
    c1, c2 = cfg.cnn_channels
    side = cfg.image_size // 4
    return {**_conv("conv0", 5, 5, cfg.in_channels, c1),
            **_conv("conv1", 5, 5, c1, c2),
            **_dense("fc0", side * side * c2, 120),
            **_dense("fc1", 120, 84),
            **_dense("head", 84, cfg.num_classes, unit_axis=None)}


def lenet_mask_schema(cfg: ModelConfig) -> Dict[str, tuple]:
    c1, c2 = cfg.cnn_channels
    return {"conv0": (1, c1), "conv1": (1, c2), "fc0": (1, 120), "fc1": (1, 84)}


def lenet_fwd(params, x, cfg, masks=None, kernels=None, mask_block=128):
    x = jnp.tanh(conv2d(x, params["conv0_w"], params["conv0_b"]))
    x = _apply(x, _m(masks, "conv0"))
    x = avg_pool(x)
    x = jnp.tanh(conv2d(x, params["conv1_w"], params["conv1_b"]))
    x = _apply(x, _m(masks, "conv1"))
    x = avg_pool(x)
    x = x.reshape(x.shape[0], -1)
    x = _fc(params, x, "fc0", masks, kernels, mask_block)
    x = _fc(params, x, "fc1", masks, kernels, mask_block)
    return x @ params["head_w"] + params["head_b"]


# ---------------------------------------------------------------------------
# AlexNet (CIFAR-scale)
# ---------------------------------------------------------------------------


def alexnet_spec(cfg: ModelConfig):
    cs = cfg.cnn_channels
    spec = {}
    cin = cfg.in_channels
    for i, c in enumerate(cs):
        spec.update(_conv(f"conv{i}", 3, 3, cin, c))
        cin = c
    side = cfg.image_size // 8
    spec.update(_dense("fc0", side * side * cs[-1], 1024))
    spec.update(_dense("fc1", 1024, 512))
    spec.update(_dense("head", 512, cfg.num_classes, unit_axis=None))
    return spec


def alexnet_mask_schema(cfg: ModelConfig) -> Dict[str, tuple]:
    out = {f"conv{i}": (1, c) for i, c in enumerate(cfg.cnn_channels)}
    out.update({"fc0": (1, 1024), "fc1": (1, 512)})
    return out


def alexnet_fwd(params, x, cfg, masks=None, kernels=None, mask_block=128):
    cs = cfg.cnn_channels
    pool_after = {0, 1, len(cs) - 1}
    for i in range(len(cs)):
        x = jax.nn.relu(conv2d(x, params[f"conv{i}_w"], params[f"conv{i}_b"]))
        x = _apply(x, _m(masks, f"conv{i}"))
        if i in pool_after:
            x = max_pool(x)
    x = x.reshape(x.shape[0], -1)
    x = _fc(params, x, "fc0", masks, kernels, mask_block, act=jax.nn.relu)
    x = _fc(params, x, "fc1", masks, kernels, mask_block, act=jax.nn.relu)
    return x @ params["head_w"] + params["head_b"]


# ---------------------------------------------------------------------------
# ResNet-18 (GroupNorm)
# ---------------------------------------------------------------------------


def resnet18_spec(cfg: ModelConfig):
    ws = cfg.cnn_channels                     # (64, 128, 256, 512)
    spec = {**_conv("stem", 3, 3, cfg.in_channels, ws[0])}
    cin = ws[0]
    for s, w in enumerate(ws):
        for b in range(2):
            spec.update(_conv(f"s{s}b{b}c0", 3, 3, cin if b == 0 else w, w))
            spec.update(_conv(f"s{s}b{b}c1", 3, 3, w, w))
            if b == 0 and cin != w:
                spec.update(_conv(f"s{s}proj", 1, 1, cin, w))
        cin = w
    spec.update(_dense("head", ws[-1], cfg.num_classes, unit_axis=None))
    return spec


def resnet18_mask_schema(cfg: ModelConfig) -> Dict[str, tuple]:
    out = {}
    for s, w in enumerate(cfg.cnn_channels):
        for b in range(2):
            out[f"s{s}b{b}c0"] = (1, w)       # first conv of each block
    return out


def resnet18_fwd(params, x, cfg, masks=None, kernels=None, mask_block=128):
    # maskable units are conv filters only — the Pallas dense kernels have
    # no call site here; ``kernels`` is accepted for dispatch uniformity
    ws = cfg.cnn_channels
    x = jax.nn.relu(group_norm(conv2d(x, params["stem_w"], params["stem_b"])))
    cin = ws[0]
    for s, w in enumerate(ws):
        for b in range(2):
            stride = 2 if (b == 0 and s > 0) else 1
            h = conv2d(x, params[f"s{s}b{b}c0_w"], params[f"s{s}b{b}c0_b"],
                       stride=stride)
            h = jax.nn.relu(group_norm(h))
            h = _apply(h, _m(masks, f"s{s}b{b}c0"))
            h = conv2d(h, params[f"s{s}b{b}c1_w"], params[f"s{s}b{b}c1_b"])
            h = group_norm(h)
            if b == 0 and cin != w:
                x = conv2d(x, params[f"s{s}proj_w"], params[f"s{s}proj_b"],
                           stride=stride)
            elif stride != 1:
                x = avg_pool(x, stride)
            x = jax.nn.relu(x + h)
        cin = w
    x = x.mean(axis=(1, 2))
    return x @ params["head_w"] + params["head_b"]


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

_SPECS = {"lenet": lenet_spec, "alexnet": alexnet_spec, "resnet18": resnet18_spec}
_FWDS = {"lenet": lenet_fwd, "alexnet": alexnet_fwd, "resnet18": resnet18_fwd}
_SCHEMAS = {"lenet": lenet_mask_schema, "alexnet": alexnet_mask_schema,
            "resnet18": resnet18_mask_schema}


def cnn_spec(cfg):
    return _SPECS[cfg.name](cfg)


def cnn_mask_schema(cfg):
    return _SCHEMAS[cfg.name](cfg)


def cnn_logits(params, images, cfg, masks=None, kernels=None, mask_block=128):
    return _FWDS[cfg.name](params, images, cfg, masks, kernels, mask_block)


def cnn_loss(params, batch, cfg, rt=None, masks=None, active_mlp_idx=None):
    rt = rt or {}
    logits = cnn_logits(params, batch["images"], cfg, masks,
                        kernels=rt.get("kernels"),
                        mask_block=rt.get("mask_block", 128))
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def cnn_accuracy(params, images, labels, cfg, masks=None):
    logits = cnn_logits(params, images, cfg, masks)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
