"""Decoder-only LM assembly (families: dense, moe, vlm).

Homogeneous stacks use scan-over-layers with STACKED params (leading
``layers`` axis) — one traced block, short HLO, fast 512-device GSPMD
compiles (the MaxText pattern).  DeepSeek-V2's leading dense layer lives
outside the scanned MoE stack.

Helios masks enter as a dict of stacked unit masks:
  {"mlp": (L, d_ff), "heads": (L, H), "experts": (L, E)}
sliced per layer inside the scan; masked-out units are removed from the
forward pass so their parameters receive zero gradient (soft-training
semantics).  In ``compact`` mode `active_mlp_idx` (L, k) gathers the MLP
hidden units instead, shrinking the compiled matmuls (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mla, moe
from repro.models.module import stack


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def _attn_spec(cfg: ModelConfig):
    if cfg.use_mla:
        return mla.mla_spec(cfg)
    return L.attention_spec(cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                            cfg.resolved_head_dim, cfg.qkv_bias)


def _block_spec(cfg: ModelConfig, kind: str):
    spec = {
        "attn_norm": L.norm_spec(cfg.d_model, cfg.norm),
        "attn": _attn_spec(cfg),
        "mlp_norm": L.norm_spec(cfg.d_model, cfg.norm),
    }
    if kind == "moe":
        spec["moe"] = moe.moe_spec(cfg)
    else:
        spec["mlp"] = L.mlp_spec(cfg.d_model, cfg.d_ff, cfg.activation)
    return spec


def lm_spec(cfg: ModelConfig):
    spec: Dict[str, Any] = {"embed": L.embed_spec(cfg.padded_vocab,
                                                  cfg.d_model,
                                                  cfg.tie_embeddings)}
    n_dense = cfg.first_k_dense if cfg.family == "moe" else 0
    n_moe = cfg.num_layers - n_dense if cfg.family == "moe" else 0
    n_plain = cfg.num_layers if cfg.family != "moe" else 0

    if n_dense:
        spec["dense_blocks"] = stack(_block_spec(cfg, "dense"), n_dense)
    if n_moe:
        spec["moe_blocks"] = stack(_block_spec(cfg, "moe"), n_moe)
    if n_plain:
        spec["blocks"] = stack(_block_spec(cfg, "dense"), n_plain)
    spec["final_norm"] = L.norm_spec(cfg.d_model, cfg.norm)
    return spec


def mask_schema(cfg: ModelConfig) -> Dict[str, tuple]:
    """Helios maskable-unit table: key -> (num_layers, units).

    Multi-stack models (DeepSeek-V2: dense + MoE stacks) use stack-scoped
    keys ("moe_blocks:heads") so scores/masks align with each stack.
    """
    if cfg.family == "moe":
        n_moe = cfg.num_layers - cfg.first_k_dense
        if cfg.first_k_dense:
            return {"dense_blocks:heads": (cfg.first_k_dense, cfg.num_heads),
                    "moe_blocks:heads": (n_moe, cfg.num_heads),
                    "mlp": (cfg.first_k_dense, cfg.d_ff),
                    "experts": (n_moe, cfg.num_experts)}
        return {"heads": (cfg.num_layers, cfg.num_heads),
                "experts": (cfg.num_layers, cfg.num_experts)}
    return {"heads": (cfg.num_layers, cfg.num_heads),
            "mlp": (cfg.num_layers, cfg.d_ff)}


def _stack_masks(masks, name: str, kind: str, n_layers: int):
    """Per-stack mask slices with canonical keys (heads / mlp / experts)."""
    if not masks:
        return {}
    sl = {}
    hk = f"{name}:heads" if f"{name}:heads" in masks else "heads"
    if hk in masks and masks[hk].shape[0] == n_layers:
        sl["heads"] = masks[hk]
    ok = "experts" if kind == "moe" else "mlp"
    if ok in masks and masks[ok].shape[0] == n_layers:
        sl[ok] = masks[ok]
    return sl


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _mask_slice(masks, key, i):
    if masks is None or key not in masks:
        return None
    return masks[key][i]


def _block_fwd(p, x, positions, cfg, rt, *, kind: str, head_mask=None,
               mlp_mask=None, expert_mask=None, active_mlp_idx=None):
    # kernel-backed soft-training: rt["kernels"]="pallas" routes the causal
    # self-attention through the Pallas flash kernel and the masked MLP
    # through the block-sparse masked-matmul pair (MLA / MoE paths keep
    # their own lowerings — the dispatch is per call site).  The long-seq
    # "chunked" lowering is NOT overridden: the flash kernel's recompute
    # VJP materializes O(S²) scores in the backward, which is exactly what
    # chunked attention exists to avoid (native flash bwd kernel = the
    # remaining TPU work, see ROADMAP).
    kern = rt.get("kernels")
    attn_impl = "pallas" if (kern == "pallas"
                             and rt["attn_impl"] != "chunked") \
        else rt["attn_impl"]
    h = L.apply_norm(p["attn_norm"], x, cfg.norm)
    if cfg.use_mla:
        attn_out = mla.mla_fwd(p["attn"], h, positions, cfg,
                               impl=rt["attn_impl"], head_mask=head_mask)
    else:
        attn_out = L.attention_fwd(p["attn"], h, positions, theta=cfg.rope_theta,
                                   impl=attn_impl, head_mask=head_mask,
                                   rope=rt.get("rope", True),
                                   kv_spec=rt.get("kv_spec"))
    # named for the remat policy: saving attention outputs avoids
    # recomputing the S^2 attention in the backward pass (§Perf cell C)
    attn_out = checkpoint_name(attn_out, "attn_out")
    x = x + attn_out
    h = L.apply_norm(p["mlp_norm"], x, cfg.norm)
    if kind == "moe":
        y = moe.moe_fwd(p["moe"], h, cfg, expert_mask=expert_mask,
                        impl=rt["moe_impl"], moe_groups=rt["moe_groups"])
    else:
        y = L.mlp_fwd(p["mlp"], h, cfg.activation, unit_mask=mlp_mask,
                      active_idx=active_mlp_idx, kernels=kern,
                      mask_block=rt.get("mask_block", 128))
    return x + y


def _scan_stack(params_stacked, x, positions, cfg, rt, *, kind: str,
                name: str = "blocks", masks=None, active_mlp_idx=None):
    """lax.scan over stacked layer params (+ per-layer mask slices)."""
    n_layers = jax.tree.leaves(params_stacked)[0].shape[0]

    xs = {"p": params_stacked}
    sl = _stack_masks(masks, name, kind, n_layers)
    if sl:
        xs["m"] = sl
    if active_mlp_idx is not None:
        xs["idx"] = active_mlp_idx

    def body(carry, inp):
        m = inp.get("m", {})
        carry = _block_fwd(
            inp["p"], carry, positions, cfg, rt, kind=kind,
            head_mask=m.get("heads"),
            mlp_mask=m.get("mlp"),
            expert_mask=m.get("experts"),
            active_mlp_idx=inp.get("idx"))
        return carry, None

    if cfg.remat and rt.get("remat", True):
        policy = None
        if rt.get("remat_policy") == "save_attn":
            policy = jax.checkpoint_policies.save_only_these_names("attn_out")
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)
    x, _ = jax.lax.scan(body, x, xs)
    return x


def _unrolled_stack(params_stacked, x, positions, cfg, rt, *, kind: str,
                    name: str = "blocks", masks=None, active_mlp_idx=None):
    n_layers = jax.tree.leaves(params_stacked)[0].shape[0]
    sl = _stack_masks(masks, name, kind, n_layers)
    key = "experts" if kind == "moe" else "mlp"
    for i in range(n_layers):
        p = jax.tree.map(lambda t: t[i], params_stacked)
        x = _block_fwd(
            p, x, positions, cfg, rt, kind=kind,
            head_mask=_mask_slice(sl, "heads", i),
            mlp_mask=_mask_slice(sl, key, i) if key == "mlp" else None,
            expert_mask=_mask_slice(sl, key, i) if key == "experts" else None,
            active_mlp_idx=None if active_mlp_idx is None else active_mlp_idx[i])
    return x


def _stacks(params, cfg):
    """Ordered (name, kind) of layer stacks present."""
    out = []
    if "dense_blocks" in params:
        out.append(("dense_blocks", "dense"))
    if "moe_blocks" in params:
        out.append(("moe_blocks", "moe"))
    if "blocks" in params:
        out.append(("blocks", "dense"))
    return out


def _backbone(params, x, positions, cfg, rt, masks=None, active_mlp_idx=None):
    run = _scan_stack if cfg.scan_layers else _unrolled_stack
    for name, kind in _stacks(params, cfg):
        x = run(params[name], x, positions, cfg, rt, kind=kind, name=name,
                masks=masks, active_mlp_idx=active_mlp_idx)
    return L.apply_norm(params["final_norm"], x, cfg.norm)


# ---------------------------------------------------------------------------
# Entry points: train loss / prefill / decode
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch, cfg):
    """Token (+ optional image-prefix) embedding.  Returns (x, loss_mask)."""
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    loss_mask = jnp.ones(tokens.shape, x.dtype)
    if cfg.family == "vlm":
        img = batch["image_embeds"].astype(x.dtype)          # (B, Nimg, d)
        x = jnp.concatenate([img, x], axis=1)
        loss_mask = jnp.concatenate(
            [jnp.zeros(img.shape[:2], x.dtype), loss_mask], axis=1)
    return x, loss_mask


def lm_loss(params, batch, cfg: ModelConfig, rt, masks=None,
            active_mlp_idx=None):
    x, loss_mask = _embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = L.constrain(x, rt.get("act_spec"))
    h = _backbone(params, x, positions, cfg, rt, masks, active_mlp_idx)
    logits = L.constrain(L.unembed(params["embed"], h),
                         rt.get("logits_spec"))
    # next-token CE over text positions
    targets = jnp.concatenate(
        [batch["tokens"], jnp.zeros((b, 1), batch["tokens"].dtype)], axis=1)
    offset = x.shape[1] - batch["tokens"].shape[1]           # image prefix len
    tgt = targets[:, 1:]                                     # (B, S_text)
    pred = logits[:, offset:offset + tgt.shape[1]]
    mask = loss_mask[:, offset:offset + tgt.shape[1]]
    mask = mask.at[:, -1].set(0.0)                           # no target for last
    return L.cross_entropy_loss(pred, tgt, mask)


def lm_prefill(params, batch, cfg: ModelConfig, rt, masks=None):
    """Forward over the prompt; returns (last-position logits, cache)."""
    x, _ = _embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    caches = []

    # prefill keeps per-layer caches -> scan with stacked cache outputs
    for name, kind in _stacks(params, cfg):
        stackp = params[name]
        n_layers = jax.tree.leaves(stackp)[0].shape[0]

        def body(carry, inp, kind=kind):
            p = inp["p"]
            m = inp.get("m", {})
            h = L.apply_norm(p["attn_norm"], carry, cfg.norm)
            hm = m.get("heads")
            if cfg.use_mla:
                attn_out, kv = mla.mla_fwd(p["attn"], h, positions, cfg,
                                           impl=rt["attn_impl"], head_mask=hm,
                                           return_cache=True)
            else:
                attn_out, kv = L.attention_prefill(
                    p["attn"], h, positions, theta=cfg.rope_theta,
                    impl=rt["attn_impl"], head_mask=hm,
                    rope=rt.get("rope", True), kv_spec=rt.get("kv_spec"))
            x2 = carry + attn_out
            h2 = L.apply_norm(p["mlp_norm"], x2, cfg.norm)
            if kind == "moe":
                y = moe.moe_fwd(p["moe"], h2, cfg, expert_mask=m.get("experts"),
                                impl=rt["moe_impl"], moe_groups=rt["moe_groups"])
            else:
                y = L.mlp_fwd(p["mlp"], h2, cfg.activation,
                              unit_mask=m.get("mlp"))
            return x2 + y, kv

        xs = {"p": stackp}
        sl = _stack_masks(masks, name, kind, n_layers)
        if sl:
            xs["m"] = sl
        if cfg.scan_layers:
            bodyf = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
            x, kv_stack = jax.lax.scan(bodyf, x, xs)
            caches.append(kv_stack)
        else:
            kvs = []
            for i in range(n_layers):
                inp = jax.tree.map(lambda t: t[i], xs)
                x, kv = body(x, inp)
                kvs.append(kv)
            caches.append(jax.tree.map(lambda *ts: jnp.stack(ts), *kvs))

    h = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], h[:, -1:])
    cache = {"kv": caches, "pos": jnp.array(s, jnp.int32)}
    return logits[:, 0], cache


def lm_decode(params, token, cache, cfg: ModelConfig, rt, masks=None):
    """One decode step.  token: (B, 1) int32.  Returns (logits, new cache)."""
    x = L.embed(params["embed"], token)
    pos = cache["pos"]
    new_caches = []
    ci = 0
    for name, kind in _stacks(params, cfg):
        stackp = params[name]
        kv_stack = cache["kv"][ci]
        n_layers = jax.tree.leaves(stackp)[0].shape[0]

        def body(carry, inp, kind=kind):
            p, kv, m = inp["p"], inp["kv"], inp.get("m", {})
            h = L.apply_norm(p["attn_norm"], carry, cfg.norm)
            hm = m.get("heads")
            if cfg.use_mla:
                attn_out, kv_new = mla.mla_decode(p["attn"], h, kv, pos, cfg,
                                                  head_mask=hm)
            else:
                attn_out, kv_new = L.attention_decode(
                    p["attn"], h, kv, pos, theta=cfg.rope_theta, head_mask=hm,
                    rope=rt.get("rope", True),
                    kv_spec=rt.get("decode_kv_spec"))
            x2 = carry + attn_out
            h2 = L.apply_norm(p["mlp_norm"], x2, cfg.norm)
            if kind == "moe":
                y = moe.moe_fwd(p["moe"], h2, cfg, expert_mask=m.get("experts"),
                                impl=rt["moe_impl"], moe_groups=rt["moe_groups"])
            else:
                y = L.mlp_fwd(p["mlp"], h2, cfg.activation,
                              unit_mask=m.get("mlp"))
            return x2 + y, kv_new

        xs = {"p": stackp, "kv": kv_stack}
        sl = _stack_masks(masks, name, kind, n_layers)
        if sl:
            xs["m"] = sl
        if cfg.scan_layers:
            x, kv_new_stack = jax.lax.scan(body, x, xs)
            new_caches.append(kv_new_stack)
        else:
            kvs = []
            for i in range(n_layers):
                inp = jax.tree.map(lambda t: t[i], xs)
                x, kv = body(x, inp)
                kvs.append(kv)
            new_caches.append(jax.tree.map(lambda *ts: jnp.stack(ts), *kvs))
        ci += 1

    h = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], h)
    return logits[:, 0], {"kv": new_caches, "pos": pos + 1}
