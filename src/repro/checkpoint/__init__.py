from repro.checkpoint.checkpoint import (latest_step, metadata, restore, save)

__all__ = ["save", "restore", "latest_step", "metadata"]
