"""Fault-tolerant checkpointing: atomic msgpack snapshots, keep-N GC.

Any pytree of arrays (train state, FL server state including Helios masks and
skip counters, optimizer moments) round-trips.  Writes go to a temp file then
``os.replace`` (atomic on POSIX) so a crash mid-write never corrupts the
latest checkpoint; restart picks up the newest complete step.

Compression: ``zstandard`` when available, stdlib ``zlib`` otherwise.  Files
carry a 5-byte header (magic + codec flag) so either build reads the other's
checkpoints; headerless files are legacy raw-zstd frames.

Single-writer contract: one process/thread publishes into a directory at a
time (the FL loop's round-end publish hook).  Readers (the serve-while-you-
train hot-swap path) only ever see complete ``ckpt_*.msgpack.zst`` files —
in-flight ``*.tmp`` files never match the key pattern, so ``latest_step`` /
``restore`` cannot observe a partial write; ``_gc`` sweeps tmp leftovers a
crash mid-write abandoned.
"""
from __future__ import annotations

import json
import os
import re
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
    _HAVE_ZSTD = True
except ImportError:                       # optional dep: fall back to zlib
    zstandard = None
    _HAVE_ZSTD = False

_KEY_RE = re.compile(r"^ckpt_(\d+)\.msgpack\.zst$")

#: header = magic + 1-byte codec flag; the flag (not the filename) is
#: authoritative for how the payload is compressed.
_MAGIC = b"HCKP"
_CODEC_ZSTD = b"z"
_CODEC_ZLIB = b"d"


def _compress(payload: bytes) -> bytes:
    if _HAVE_ZSTD:
        return _MAGIC + _CODEC_ZSTD + \
            zstandard.ZstdCompressor(level=3).compress(payload)
    return _MAGIC + _CODEC_ZLIB + zlib.compress(payload, 6)


def _decompress(blob: bytes) -> bytes:
    if blob[:len(_MAGIC)] == _MAGIC:
        codec = blob[len(_MAGIC):len(_MAGIC) + 1]
        data = blob[len(_MAGIC) + 1:]
        if codec == _CODEC_ZLIB:
            # same decompression-bomb cap as the zstd path
            d = zlib.decompressobj()
            out = d.decompress(data, 1 << 34)
            if d.unconsumed_tail:
                raise ValueError(
                    "checkpoint payload exceeds the 16 GiB decompression cap")
            return out
        if codec == _CODEC_ZSTD:
            if not _HAVE_ZSTD:
                raise RuntimeError(
                    "checkpoint was written with zstandard, which is not "
                    "installed; install the 'zstd' extra to read it")
            return zstandard.ZstdDecompressor().decompress(
                data, max_output_size=1 << 34)
        raise ValueError(f"unknown checkpoint codec flag {codec!r}")
    # legacy format: headerless raw zstd frame
    if not _HAVE_ZSTD:
        raise RuntimeError(
            "legacy zstd checkpoint requires the zstandard package")
    return zstandard.ZstdDecompressor().decompress(blob,
                                                   max_output_size=1 << 34)


def _flatten(tree, path=()):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], path + (str(k),)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, path + (f"<{i}>",)))
        if len(tree) == 0:
            out["/".join(path) + "/<empty>"] = np.zeros((0,), np.int8)
    else:
        out["/".join(path)] = np.asarray(tree)
    return out


def _pack_leaf(arr: np.ndarray):
    return {"dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _unpack_leaf(d) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=d["dtype"]).reshape(d["shape"])


def save(directory: str, step: int, tree: Any, keep: int = 3,
         metadata: Optional[dict] = None) -> str:
    if keep < 1:
        # keep=0 used to make steps[:-keep] the EMPTY slice in _gc, so GC
        # silently kept everything; fail loudly instead of guessing intent
        raise ValueError(f"keep must be >= 1 (the newest checkpoint is "
                         f"never GC'd), got {keep}")
    os.makedirs(directory, exist_ok=True)
    flat = {k: _pack_leaf(v) for k, v in _flatten(jax.device_get(tree)).items()}
    payload = msgpack.packb({"step": step, "leaves": flat,
                             "metadata": json.dumps(metadata or {})})
    comp = _compress(payload)
    final = os.path.join(directory, f"ckpt_{step}.msgpack.zst")
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        f.write(comp)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)                    # atomic publish
    _gc(directory, keep)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := _KEY_RE.match(f))]
    return max(steps) if steps else None


def restore(directory: str, target: Any, step: Optional[int] = None):
    """Restore into the structure of ``target`` (shapes/dtypes preserved).

    Returns (tree, step).  Raises FileNotFoundError when no checkpoint exists.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step}.msgpack.zst")
    with open(path, "rb") as f:
        raw = _decompress(f.read())
    obj = msgpack.unpackb(raw)
    flat = {k: _unpack_leaf(v) for k, v in obj["leaves"].items()}

    def rebuild(node, path=()):
        if isinstance(node, dict):
            return {k: rebuild(v, path + (str(k),)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [rebuild(v, path + (f"<{i}>",)) for i, v in enumerate(node)]
            if isinstance(node, tuple):
                # NamedTuple containers (optimizer states) construct from
                # positional fields — plain tuple(t) would collapse them
                # into a different pytree type than the target
                return type(node)(*t) if hasattr(node, "_fields") \
                    else tuple(t)
            return type(node)(t)
        key = "/".join(path)
        arr = flat[key]
        leaf = np.asarray(node)
        if tuple(arr.shape) != leaf.shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"target {leaf.shape}")
        if hasattr(node, "dtype") and isinstance(node, jax.Array):
            return jnp.asarray(arr.astype(leaf.dtype))
        return arr.astype(leaf.dtype)

    return rebuild(target), step


def metadata(directory: str, step: Optional[int] = None) -> dict:
    if step is None:
        step = latest_step(directory)
        if step is None:
            # same clean error as restore() — not the baffling
            # "ckpt_None.msgpack.zst" FileNotFoundError
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step}.msgpack.zst")
    with open(path, "rb") as f:
        raw = _decompress(f.read())
    return json.loads(msgpack.unpackb(raw)["metadata"])


def _gc(directory: str, keep: int):
    if keep < 1:
        raise ValueError(f"keep must be >= 1, got {keep}")
    names = os.listdir(directory)
    steps = sorted(int(m.group(1)) for f in names if (m := _KEY_RE.match(f)))
    for s in steps[:-keep]:
        try:
            os.remove(os.path.join(directory, f"ckpt_{s}.msgpack.zst"))
        except OSError:
            pass
    # sweep tmp leftovers from a crash mid-write (single-writer contract:
    # the only live tmp is save()'s own, already os.replace'd by now)
    for f in names:
        if f.endswith(".tmp") and _KEY_RE.match(f[:-len(".tmp")]):
            try:
                os.remove(os.path.join(directory, f))
            except OSError:
                pass
