"""Fault-tolerant checkpointing: atomic msgpack+zstd snapshots, keep-N GC.

Any pytree of arrays (train state, FL server state including Helios masks and
skip counters, optimizer moments) round-trips.  Writes go to a temp file then
``os.replace`` (atomic on POSIX) so a crash mid-write never corrupts the
latest checkpoint; restart picks up the newest complete step.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import zstandard

_KEY_RE = re.compile(r"^ckpt_(\d+)\.msgpack\.zst$")


def _flatten(tree, path=()):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], path + (str(k),)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, path + (f"<{i}>",)))
        if len(tree) == 0:
            out["/".join(path) + "/<empty>"] = np.zeros((0,), np.int8)
    else:
        out["/".join(path)] = np.asarray(tree)
    return out


def _pack_leaf(arr: np.ndarray):
    return {"dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _unpack_leaf(d) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=d["dtype"]).reshape(d["shape"])


def save(directory: str, step: int, tree: Any, keep: int = 3,
         metadata: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = {k: _pack_leaf(v) for k, v in _flatten(jax.device_get(tree)).items()}
    payload = msgpack.packb({"step": step, "leaves": flat,
                             "metadata": json.dumps(metadata or {})})
    comp = zstandard.ZstdCompressor(level=3).compress(payload)
    final = os.path.join(directory, f"ckpt_{step}.msgpack.zst")
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        f.write(comp)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)                    # atomic publish
    _gc(directory, keep)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := _KEY_RE.match(f))]
    return max(steps) if steps else None


def restore(directory: str, target: Any, step: Optional[int] = None):
    """Restore into the structure of ``target`` (shapes/dtypes preserved).

    Returns (tree, step).  Raises FileNotFoundError when no checkpoint exists.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step}.msgpack.zst")
    raw = zstandard.ZstdDecompressor().decompress(
        open(path, "rb").read(), max_output_size=1 << 34)
    obj = msgpack.unpackb(raw)
    flat = {k: _unpack_leaf(v) for k, v in obj["leaves"].items()}

    def rebuild(node, path=()):
        if isinstance(node, dict):
            return {k: rebuild(v, path + (str(k),)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [rebuild(v, path + (f"<{i}>",)) for i, v in enumerate(node)]
            return type(node)(t) if not isinstance(node, tuple) else tuple(t)
        key = "/".join(path)
        arr = flat[key]
        leaf = np.asarray(node)
        if tuple(arr.shape) != leaf.shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"target {leaf.shape}")
        if hasattr(node, "dtype") and isinstance(node, jax.Array):
            return jnp.asarray(arr.astype(leaf.dtype))
        return arr.astype(leaf.dtype)

    return rebuild(target), step


def metadata(directory: str, step: Optional[int] = None) -> dict:
    if step is None:
        step = latest_step(directory)
    path = os.path.join(directory, f"ckpt_{step}.msgpack.zst")
    raw = zstandard.ZstdDecompressor().decompress(
        open(path, "rb").read(), max_output_size=1 << 34)
    return json.loads(msgpack.unpackb(raw)["metadata"])


def _gc(directory: str, keep: int):
    steps = sorted(int(m.group(1)) for f in os.listdir(directory)
                   if (m := _KEY_RE.match(f)))
    for s in steps[:-keep]:
        try:
            os.remove(os.path.join(directory, f"ckpt_{s}.msgpack.zst"))
        except OSError:
            pass
