"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.

Assigned: 38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]

38 Mamba2 layers; ONE shared transformer block (32-head attention + d_ff=8192
MLP, weights shared across invocations) applied every 6 Mamba2 layers, as in
the Zamba2 design.  Sub-quadratic → runs the long_500k cell (SSM state decode
is O(1) in context; the shared attn block attends over the long KV cache
linearly per decoded token).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    attn_every=6,
    activation="silu",
    scan_layers=False,         # heterogeneous layer schedule → unrolled
)
