"""The assigned input-shape suite (identical for all 10 LM archs).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
seq_len-deep KV/SSM cache), ``prefill_*`` lowers ``prefill_step`` and
``train_*`` lowers ``train_step``.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig

SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a cell runs, plus the skip reason (recorded in EXPERIMENTS.md).

    Per assignment: ``long_500k`` needs sub-quadratic attention — skipped for
    pure full-attention archs, run for SSM/hybrid.  No encoder-only archs are
    assigned (seamless is enc-dec → its decoder serves decode shapes).
    """
    if shape.name == "long_500k" and not model.is_subquadratic:
        return False, "full-attention arch: 524k context infeasible (noted in DESIGN.md)"
    return True, ""


def cells(models: dict[str, ModelConfig]):
    """All (arch x shape) cells with applicability."""
    out = []
    for mname, mcfg in models.items():
        for sname, scfg in SHAPES.items():
            ok, why = applicable(mcfg, scfg)
            out.append((mname, sname, ok, why))
    return out
