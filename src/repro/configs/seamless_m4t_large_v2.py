"""seamless-m4t-large-v2 [audio] — encoder-decoder multimodal backbone.

Assigned: 24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206
[arXiv:2308.11596; hf]

The modality frontend (w2v-BERT speech encoder feature extractor) is a STUB
per the assignment: ``input_specs()`` provides precomputed frame embeddings of
shape (batch, enc_len, d_model).  "24L" is realized as 24 encoder + 24 decoder
layers (the published text-to-text backbone of M4T-large uses 24/24; recorded
in DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=48,            # 24 enc + 24 dec
    enc_layers=24,
    dec_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,        # padded_vocab -> 256256 for clean 16-way sharding
    norm="layernorm",
    activation="gelu",        # NLLB/M4T uses ReLU/GELU-family FFN, not gated
    qkv_bias=True,
    tie_embeddings=True,
)
