"""internvl2-1b [vlm] — InternViT frontend (stub) + InternLM2/Qwen2-0.5B LM.

Assigned: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655
[arXiv:2404.16821; hf]

The vision frontend is a STUB per the assignment: ``input_specs()`` provides
256 precomputed patch embeddings (448px, patch 14, pixel-unshuffle x0.5 →
1024/4 = 256 tokens) of shape (batch, 256, d_model) prepended to the text
sequence.  14 heads do not divide the 16-way model axis → attention heads
replicate while d_ff = 4864 = 16·304 tensor-shards (see parallel/sharding.py
fallback solver).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,         # padded_vocab -> 151680
    qkv_bias=True,
    num_image_tokens=256,
    activation="silu",
    tie_embeddings=True,
)
