"""granite-moe-1b-a400m [moe] — IBM Granite 3.0 1B-A400M base.

Assigned: 24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

d_ff=512 is the PER-EXPERT hidden size (32 experts, top-8 routing).
Expert-level soft-training (rotating which experts train) is the natural
Helios unit here — see DESIGN.md §4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    moe_d_ff=512,
    num_experts=32,
    num_experts_per_tok=8,
    num_shared_experts=0,
    vocab_size=49155,          # padded_vocab -> 49280
    activation="silu",
    tie_embeddings=True,
)
