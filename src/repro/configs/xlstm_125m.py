"""xlstm-125m [ssm] — sLSTM + mLSTM block stack.

Assigned: 12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304, sLSTM + mLSTM
[arXiv:2405.04517; unverified]

d_ff=0: xLSTM blocks carry their own up-projection (factor 2 for mLSTM,
4/3-style gated FFN folded into the sLSTM block); there is no separate FFN.
Block schedule: sLSTM at positions (5, 11), mLSTM elsewhere (the paper's
mostly-mLSTM ratio).  Sub-quadratic → runs long_500k (recurrent state decode).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_layers=(5, 11),
    norm="layernorm",
    activation="gelu",
    tie_embeddings=True,
    scan_layers=False,         # mixed block types → unrolled
)
