"""qwen2.5-32b [dense] — Qwen2.5 32B (GQA kv=8, QKV bias).

Assigned: 64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064
[hf:Qwen/Qwen2.5-0.5B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    activation="silu",
)
