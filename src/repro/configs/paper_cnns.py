"""The paper's own testbed models (Section VII.A): LeNet / AlexNet / ResNet-18.

Trained on synthetic MNIST / CIFAR-10 / CIFAR-100 shaped data (offline
container — see data/synthetic.py).  These are the faithful-reproduction
models for Fig. 5-7 and Table I.
"""
from repro.configs.base import ModelConfig

LENET = ModelConfig(
    name="lenet",
    family="cnn",
    num_layers=5,
    d_model=0, num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=0,
    image_size=28, in_channels=1, num_classes=10,
    cnn_channels=(6, 16),          # conv stages; then 120-84-10 dense head
    scan_layers=False, remat=False,
)

ALEXNET = ModelConfig(
    name="alexnet",
    family="cnn",
    num_layers=8,
    d_model=0, num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=0,
    image_size=32, in_channels=3, num_classes=10,
    cnn_channels=(64, 192, 384, 256, 256),   # CIFAR-scale AlexNet
    scan_layers=False, remat=False,
)

RESNET18 = ModelConfig(
    name="resnet18",
    family="cnn",
    num_layers=18,
    d_model=0, num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=0,
    image_size=32, in_channels=3, num_classes=100,
    cnn_channels=(64, 128, 256, 512),        # stage widths, 2 blocks each
    scan_layers=False, remat=False,
)

CNNS = {c.name: c for c in (LENET, ALEXNET, RESNET18)}
