"""deepseek-v2-236b [moe] — DeepSeek-V2 with Multi-head Latent Attention.

Assigned: 60L d_model=5120 128H (GQA kv=128) d_ff=1536 vocab=102400,
MoE 160e top-6 — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf]

MLA dims follow the published config: q_lora_rank=1536, kv_lora_rank=512,
qk_nope/rope head dims 128/64, v_head_dim=128.  The first layer is dense
(first_k_dense_replace=1, d_ff=12288) as in the release.  The MLA latent
bottleneck is NOT a Helios-maskable unit (shared across heads) — heads and
routed experts are masked instead (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,          # notional under MLA (latent cache is shared)
    d_ff=12288,                # dense first layer FFN
    moe_d_ff=1536,             # per routed/shared expert
    num_experts=160,
    num_experts_per_tok=6,
    num_shared_experts=2,
    first_k_dense=1,
    vocab_size=102400,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    activation="silu",
)
