"""Config registry: ``--arch <id>`` lookup + reduced smoke-test configs."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (HeliosConfig, MeshConfig, ModelConfig,
                                RunConfig, ShapeConfig, TrainConfig)
from repro.configs.shapes import SHAPES, applicable, cells

from repro.configs import (codeqwen1_5_7b, deepseek_7b, deepseek_v2_236b,
                           granite_moe_1b_a400m, internvl2_1b, paper_cnns,
                           qwen1_5_32b, qwen2_5_32b, seamless_m4t_large_v2,
                           xlstm_125m, zamba2_1_2b)

#: The 10 assigned architectures, keyed by their public ids.
ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        seamless_m4t_large_v2,
        granite_moe_1b_a400m,
        deepseek_v2_236b,
        deepseek_7b,
        qwen1_5_32b,
        qwen2_5_32b,
        codeqwen1_5_7b,
        zamba2_1_2b,
        xlstm_125m,
        internvl2_1b,
    )
}

#: Paper testbed CNNs (LeNet / AlexNet / ResNet-18).
CNNS = paper_cnns.CNNS

ALL_MODELS: dict[str, ModelConfig] = {**ARCHS, **CNNS}


def get_model_config(name: str) -> ModelConfig:
    try:
        return ALL_MODELS[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ALL_MODELS)}") from None


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def _scale_layers(cfg: ModelConfig, n: int) -> dict:
    upd: dict = {"num_layers": n}
    if cfg.family == "encdec":
        upd.update(enc_layers=max(1, n // 2), dec_layers=max(1, n // 2),
                   num_layers=2 * max(1, n // 2))
    if cfg.slstm_layers:
        upd["slstm_layers"] = (1,)           # keep one sLSTM in the reduced stack
    if cfg.attn_every:
        upd["attn_every"] = 2
    if cfg.first_k_dense:
        upd["first_k_dense"] = 1
    return upd


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests.

    Small layers/width, few experts, tiny embedding tables — exercises every
    structural feature (GQA ratio, MLA, shared experts, hybrid schedule, ...)
    at toy scale.  FULL configs are only ever lowered abstractly (dry-run).
    """
    if cfg.family == "cnn":
        return dataclasses.replace(
            cfg, cnn_channels=tuple(max(4, c // 8) for c in cfg.cnn_channels),
            image_size=min(cfg.image_size, 16))

    kv_ratio = max(1, cfg.num_heads // max(1, cfg.num_kv_heads))
    heads = 4 if cfg.num_heads % 2 == 0 else 3   # keep odd-head quirk (internvl2)
    kv = max(1, heads // min(kv_ratio, heads))
    upd = dict(
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=256,
        **_scale_layers(cfg, 4),
    )
    if cfg.family == "moe":
        upd.update(num_experts=8,
                   num_experts_per_tok=min(2, cfg.num_experts_per_tok),
                   moe_d_ff=32)
    if cfg.use_mla:
        upd.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                   qk_rope_head_dim=8, v_head_dim=16)
    if cfg.family in ("hybrid", "ssm"):
        upd.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
    if cfg.num_image_tokens:
        upd.update(num_image_tokens=8)
    return dataclasses.replace(cfg, **upd)


SMOKE_SHAPE = ShapeConfig("smoke", "train", seq_len=64, global_batch=2)

__all__ = [
    "ARCHS", "CNNS", "ALL_MODELS", "SHAPES", "SMOKE_SHAPE",
    "ModelConfig", "ShapeConfig", "HeliosConfig", "TrainConfig", "MeshConfig",
    "RunConfig", "get_model_config", "get_shape", "reduced", "applicable",
    "cells",
]
