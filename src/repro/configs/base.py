"""Configuration dataclasses for the Helios reproduction framework.

Every run is described by four orthogonal configs:

* :class:`ModelConfig`   — architecture hyper-parameters (one per assigned arch).
* :class:`ShapeConfig`   — the workload shape (seq_len x global_batch x kind).
* :class:`HeliosConfig`  — the paper's technique: soft-training knobs (Section IV-VI).
* :class:`TrainConfig`   — optimizer / precision / remat / microbatching.

Configs are plain frozen dataclasses so they hash (usable as jit static args)
and serialize trivially into checkpoints.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``family`` selects the model assembly:
      dense | moe | encdec | hybrid | ssm | vlm | cnn
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                      # 0 -> d_model // num_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"                  # rmsnorm | layernorm
    activation: str = "silu"               # silu (SwiGLU) | gelu
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # ---- MoE ----
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                      # per-expert hidden size
    first_k_dense: int = 0                 # leading dense layers (DeepSeek-V2)

    # ---- MLA (DeepSeek-V2) ----
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- SSM / hybrid (Mamba2, Zamba2) ----
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0                    # hybrid: shared attn block period

    # ---- xLSTM ----
    slstm_layers: Tuple[int, ...] = ()     # indices that are sLSTM (rest mLSTM)

    # ---- enc-dec ----
    enc_layers: int = 0
    dec_layers: int = 0

    # ---- VLM ----
    num_image_tokens: int = 0              # stub frontend: precomputed patch embeds

    # ---- CNN (paper testbed) ----
    image_size: int = 0
    in_channels: int = 0
    num_classes: int = 0
    cnn_channels: Tuple[int, ...] = ()

    # ---- assembly knobs ----
    scan_layers: bool = True               # lax.scan over stacked layer params
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the `vocab` axis shards over 16-way model axis."""
        return _round_up(self.vocab_size, 128)

    @property
    def is_subquadratic(self) -> bool:
        """True when decode at 500k context is feasible (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    def n_params(self) -> int:
        """Analytic parameter count (used for 6ND roofline + volume targets)."""
        d, h = self.d_model, self.num_heads
        hd = self.resolved_head_dim
        kv = self.num_kv_heads
        V = self.padded_vocab

        def attn_params() -> int:
            if self.use_mla:
                p = d * self.q_lora_rank + self.q_lora_rank * h * (
                    self.qk_nope_head_dim + self.qk_rope_head_dim)
                p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                p += self.kv_lora_rank * h * (self.qk_nope_head_dim + self.v_head_dim)
                p += h * self.v_head_dim * d
                return p
            p = d * h * hd + 2 * d * kv * hd + h * hd * d
            if self.qkv_bias:
                p += (h + 2 * kv) * hd
            return p

        def mlp_params(ff: int) -> int:
            mults = 3 if self.activation == "silu" else 2
            return mults * d * ff

        def moe_layer() -> int:
            p = d * self.num_experts                      # router
            p += self.num_experts * mlp_params(self.moe_d_ff)
            p += self.num_shared_experts * mlp_params(self.moe_d_ff)
            return p

        emb = V * d if self.tie_embeddings else 2 * V * d

        if self.family == "moe":
            dense = self.first_k_dense
            total = emb
            total += dense * (attn_params() + mlp_params(self.d_ff))
            total += (self.num_layers - dense) * (attn_params() + moe_layer())
            return total
        if self.family == "encdec":
            enc = self.enc_layers * (attn_params() + mlp_params(self.d_ff))
            dec = self.dec_layers * (2 * attn_params() + mlp_params(self.d_ff))
            return emb + enc + dec
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            mamba = (d * (2 * d_in + 2 * self.ssm_state * 0 + 0)
                     + d * 2 * d_in          # in_proj x/z
                     + d * 2 * nheads * self.ssm_state // nheads * 0)
            # simpler: measured from spec at init; rough analytic here
            mamba = d * 2 * d_in + d_in * d + 3 * d_in  # in/out proj + dt/A/D
            mamba += d * 2 * self.ssm_state * (d_in // self.ssm_head_dim) // max(
                1, d_in // self.ssm_head_dim) * 0
            per_attn = attn_params() + mlp_params(self.d_ff)
            n_attn = (self.num_layers + self.attn_every - 1) // self.attn_every if self.attn_every else 0
            return emb + self.num_layers * mamba + per_attn  # attn block is SHARED
        if self.family == "ssm":
            # xLSTM: per block up-proj(2x) + gates; rough 8*d^2
            return emb + self.num_layers * 8 * d * d
        if self.family == "vlm":
            return emb + self.num_layers * (attn_params() + mlp_params(self.d_ff))
        if self.family == "cnn":
            return 0  # counted at init time
        return emb + self.num_layers * (attn_params() + mlp_params(self.d_ff))

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        mults = 3 if self.activation == "silu" else 2
        expert = mults * d * self.moe_d_ff
        active_per_layer = (self.num_experts_per_tok + self.num_shared_experts) * expert
        dense_per_layer = self.num_experts * expert + self.num_shared_experts * expert
        total = self.n_params()
        moe_layers = self.num_layers - self.first_k_dense
        return total - moe_layers * (dense_per_layer - active_per_layer) - \
            moe_layers * 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One workload cell: (kind, seq_len, global_batch)."""

    name: str
    kind: str                  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch          # one new token per sequence
        return self.global_batch * self.seq_len


@dataclasses.dataclass(frozen=True)
class HeliosConfig:
    """Soft-training knobs (paper Sections IV-VI)."""

    enabled: bool = True
    mode: str = "masked"                  # masked (paper-faithful) | compact (TPU-native)
    p_s: float = 0.1                      # top-contribution fraction (Section VI.A: 0.05-0.1)
    volume_levels: Tuple[float, ...] = (1.0, 0.75, 0.5, 0.25)
    contribution: str = "delta"           # delta (Eq.1) | grad_ema
    contribution_ema: float = 0.9
    # rotation regulation (Section VI.A): threshold = 1 + m / sum(p_i n_i)
    rotation_threshold_auto: bool = True
    rotation_threshold: int = 4
    # block-aligned selection (beyond-paper, DESIGN.md §2): run Eq. 2 at
    # this unit-block granularity (block-pooled scores -> block-constant
    # masks keeping ~P·n units) so the Pallas masked-matmul kernels SKIP
    # dead blocks structurally without inflating the compressed volume
    # (match the kernel block_n, 128 on TPU).  0 = unit-granular (paper-
    # exact).
    mask_block: int = 0
    # aggregation (Section VI.B)
    aggregation: str = "alpha_weighted"   # alpha_weighted (Eq.10) | masked_mean | uniform
    # identification (Section IV.B)
    identification: str = "resource"      # resource | time
    probe_iters: int = 3                  # time-based approximation test bench
    # volume adaptation (Section IV.C): move P toward deadline match
    adapt_volume: bool = True
    adapt_gain: float = 0.5
    min_volume: float = 0.125

    def units(self) -> Tuple[str, ...]:
        """Logical axes treated as maskable neuron groups."""
        return ("mlp", "heads", "experts", "ssm_heads", "filters")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    microbatches: int = 1                  # grad accumulation via lax.scan
    local_steps: int = 1                   # FL local epochs per round (local-SGD fusion)
    # uplink gradient compression (refs [19][20]) — beyond-paper distributed trick
    compress_topk: float = 0.0             # 0 = off; else fraction of coords kept
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Top-level bundle."""

    model: ModelConfig
    shape: ShapeConfig
    helios: HeliosConfig = HeliosConfig()
    train: TrainConfig = TrainConfig()
    mesh: MeshConfig = MeshConfig()
