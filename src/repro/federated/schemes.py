"""Pluggable per-scheme update policies — the algorithm seam.

The engines in :mod:`repro.federated.runtime` know HOW to execute a round
(sequentially, batched under one vmap, shard_mapped over a client mesh, or
event-driven in buckets); a :class:`Scheme` says WHAT a round means for one
algorithm: which clients soft-train, which HeliosConfig they see, how the
server aggregates, how a straggler's simulated volume enters the cohort
sampler and the round clock, and any extra per-round state (control
variates, stale-base snapshots).  It is the same move
:class:`repro.federated.adapter.FamilyAdapter` made for model families —
the engines stay scheme-blind, so every scheme runs unchanged on all four
engines and the cross-engine equivalence walls pin them together.

Paper schemes (Helios §VII.A ablations)::

  helios   — soft-training stragglers + Eq. 10 aggregation (this paper)
  syn      — Synchronized FL: everyone trains the full model, wait for all
  asyn     — Asynchronous FL: updates mixed on arrival, no waiting
  afo      — Asynchronous Federated Optimization: staleness-discounted mix
  random   — Caldas et al. [12]: random sub-model, no top-k / rotation
  st_only  — soft-training WITHOUT the Eq. 10 optimization (§VII.C)

Published straggler baselines (PAPERS.md), for the head-to-head gauntlet::

  scaffold — SCAFFOLD control variates (Karimireddy et al.): every client
             trains the FULL model with its gradient corrected by
             c_global - c_i; straggler drift is attacked with variance
             reduction instead of sub-models, at 2x uplink (the control
             delta rides along dense).
  fluid    — FLuID invariant dropout (Wang et al.): stragglers train a
             sub-model chosen by pure update-magnitude top-k ("invariant"
             neurons stay frozen) — exactly Eq. 2 masking at p_s = 1.0
             with rotation regulation disabled — and the server patches
             sub-updates in with masked-mean aggregation.
  delayed  — delayed-gradient hybrid (Xu et al.): stragglers train the
             FULL model from a D-round-stale global snapshot; their
             updates are staleness-discounted and folded into the normal
             synchronous aggregation, so the round clock is set by the
             capable cohort alone.

Adding a scheme: subclass :class:`Scheme`, set the class flags, override
the hooks you need, and register the class in :data:`SCHEMES`.  The
engines consult ONLY this interface — grep runtime.py for ``_scheme`` to
see every touch point (and tests/test_schemes.py asserts no inline
scheme-string comparison ever reappears there).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Type

import jax
import jax.numpy as jnp

from repro.configs.base import HeliosConfig
from repro.core import aggregation as AG
from repro.optim import compression as CP


def _random_hcfg(hcfg: HeliosConfig) -> HeliosConfig:
    """Caldas et al. [12] baseline: pure random selection, no top-k /
    rotation.  Shared by all engines so the baseline stays one definition."""
    return dataclasses.replace(hcfg, p_s=0.0, rotation_threshold_auto=False,
                               rotation_threshold=10 ** 9)


def _fluid_hcfg(hcfg: HeliosConfig) -> HeliosConfig:
    """FLuID invariant dropout as an Eq. 2 special case: p_s = 1.0 makes
    selection pure top-k on the invariance scores (k_top == k_total in
    core.selection.select_masks) and an unreachable rotation threshold
    keeps invariant neurons frozen (FLuID has no rotation regulation)."""
    return dataclasses.replace(hcfg, p_s=1.0, rotation_threshold_auto=False,
                               rotation_threshold=10 ** 9)


class Scheme:
    """One federated algorithm's policy surface.

    Class flags are STATIC (read at trace/build time, so they can gate
    traced code without runtime branching); hooks run on the host per
    round/client.  The base class is the common synchronous full-model
    policy; subclasses flip flags and override hooks.
    """

    name = "base"
    #: stragglers run Eq. 2 mask selection + helios_state evolution
    soft_training = False
    #: native event-driven scheme (bucketed async engine); everything else
    #: runs the synchronous template (run_async falls back to the
    #: sequential event reference)
    async_native = False
    #: asynchronously mixed updates are discounted by (staleness+1)^-a
    staleness_discount = False
    #: §IV.C volume adaptation moves straggler volumes toward the pace
    adapt_volume = False
    #: cycle scores come from the local update delta (False = reuse the
    #: previous scores, the random baseline's no-op)
    use_delta_scores = True
    #: SCAFFOLD-style control variates: local training is corrected by
    #: c_global - c_i and the engines thread control rows through the
    #: round programs
    uses_control = False
    #: delayed-gradient hybrid: stragglers train from a stale snapshot and
    #: their update is virtualized onto the current global
    uses_stale_base = False
    #: simulated cycle cost: stragglers work at full volume (no sub-model)
    full_volume = False
    #: extra dense fp32 pytrees uploaded per update (control deltas)
    extra_dense_uplink = 0

    def manifest(self) -> Dict[str, object]:
        """Flag census for the run manifest (repro.obs): which policy
        switches this scheme flips, so a run log names its algorithm
        unambiguously even after flags gain new defaults."""
        return {"name": self.name,
                "soft_training": self.soft_training,
                "async_native": self.async_native,
                "staleness_discount": self.staleness_discount,
                "adapt_volume": self.adapt_volume,
                "use_delta_scores": self.use_delta_scores,
                "uses_control": self.uses_control,
                "uses_stale_base": self.uses_stale_base,
                "full_volume": self.full_volume,
                "extra_dense_uplink": self.extra_dense_uplink}

    # -- per-round policy ----------------------------------------------
    def effective_hcfg(self, hcfg: HeliosConfig) -> HeliosConfig:
        """The HeliosConfig soft-training actually sees (one definition
        for begin_cycle AND end_cycle, every engine)."""
        return hcfg

    def agg_mode(self, hcfg: HeliosConfig) -> str:
        """Server aggregation mode (core.aggregation)."""
        return "uniform"

    def effective_volume(self, client) -> float:
        """The volume a client's simulated cycle time is billed at — the
        ONE definition both the time_weighted cohort sampler and
        _round_times consult (the pre-seam code duplicated this
        expression and relied on keeping the copies mirrored by hand)."""
        if self.full_volume or not client.is_straggler:
            return 1.0
        return client.volume

    def round_duration(self, times, cclients) -> float:
        """Simulated wall-clock one synchronous round costs (the critical
        path over the cohort)."""
        return max(times)

    def async_weight(self, mix_weight: float, stale: int,
                     staleness_a: float) -> float:
        """Per-event mix weight in the sequential async reference."""
        if self.staleness_discount:
            return mix_weight * AG.staleness_weight(stale, staleness_a)
        return mix_weight

    # -- extra per-run state (control variates, snapshot rings) ---------
    def init_run(self, run) -> None:
        """Attach scheme-owned state to a freshly constructed run."""

    def round_start(self, run) -> None:
        """Host hook before a sync round's cohort trains."""

    def round_end(self, run) -> None:
        """Host hook after a sync round aggregated."""


class HeliosScheme(Scheme):
    name = "helios"
    soft_training = True
    adapt_volume = True

    def agg_mode(self, hcfg):
        return hcfg.aggregation


class StOnlyScheme(Scheme):
    """Helios soft-training WITHOUT Eq. 10 aggregation (§VII.C)."""
    name = "st_only"
    soft_training = True


class RandomScheme(Scheme):
    """Caldas et al. [12]: random sub-model of the expected volume."""
    name = "random"
    soft_training = True
    use_delta_scores = False

    def effective_hcfg(self, hcfg):
        return _random_hcfg(hcfg)


class SynScheme(Scheme):
    """Synchronized FL: full models, wait for the slowest."""
    name = "syn"
    full_volume = True


class AsynScheme(Scheme):
    """Asynchronous FL: constant-weight mixing on arrival."""
    name = "asyn"
    async_native = True


class AfoScheme(Scheme):
    """Asynchronous Federated Optimization: staleness-discounted mixing."""
    name = "afo"
    async_native = True
    staleness_discount = True


class ScaffoldScheme(Scheme):
    """SCAFFOLD control variates (option II, the practical variant).

    Every client trains the FULL model; the local gradient is corrected
    by ``c_global - c_i`` each step, and after K local steps the client's
    control updates as ``c_i+ = c_i - c_global + (x - y) / (K * lr)``
    (the average update direction it just applied).  The server folds
    ``c_global += sum(dc) / N`` once per round.  Client controls live in
    a lazily-materialized :class:`repro.optim.compression.HostErrorStore`
    (zero rows ARE the correct SCAFFOLD init), so a million-client
    population only pays for clients that trained.  Control deltas ride
    the uplink dense (``extra_dense_uplink`` — the scheme's documented
    2x communication cost); the param delta still goes through the
    uplink codec.
    """
    name = "scaffold"
    full_volume = True
    uses_control = True
    extra_dense_uplink = 1

    def init_run(self, run) -> None:
        run._c_global = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), run.global_params)
        run._ctrl_store = CP.HostErrorStore(run.global_params)
        run._dc_buf = []


class FluidScheme(Scheme):
    """FLuID invariant dropout: Eq. 2 masking at p_s = 1.0 (pure
    update-magnitude top-k, rotation disabled) + masked-mean patching."""
    name = "fluid"
    soft_training = True
    adapt_volume = True

    def effective_hcfg(self, hcfg):
        return _fluid_hcfg(hcfg)

    def agg_mode(self, hcfg):
        return "masked_mean"


class DelayedScheme(Scheme):
    """Delayed-gradient hybrid: stragglers train the FULL model from a
    ``delay``-round-stale global (a host-driven fp32
    :class:`repro.core.aggregation.SnapshotRing`), and their update is
    virtualized onto the fresh global with a staleness discount::

        p_virtual = global + (stale+1)^-a * (y - base)

    so it rides the normal uniform aggregation.  Capable rows have
    ``base == global`` and discount 1, i.e. exactly their trained params.
    Stragglers never gate the round clock (:meth:`round_duration` is the
    capable-cohort critical path) — that is the scheme's entire wall-clock
    win in the gauntlet.
    """
    name = "delayed"
    full_volume = True
    uses_stale_base = True
    staleness_discount = True          # async fallback mixes like afo
    #: stragglers read the global from this many rounds back
    delay = 2
    staleness_a = 0.5

    def init_run(self, run) -> None:
        run._delay_ring = AG.SnapshotRing(run.global_params,
                                          cap=self.delay + 1, n_anchors=0)

    def round_start(self, run) -> None:
        agg = max(0, run.round - self.delay)
        run._stale_base = run._delay_ring.read(agg)
        run._stale_disc = float(AG.staleness_weight(
            min(run.round, self.delay), self.staleness_a))

    def round_end(self, run) -> None:
        run._delay_ring.put(run.round + 1, run.global_params)

    def round_duration(self, times, cclients) -> float:
        capable = [t for t, c in zip(times, cclients) if not c.is_straggler]
        return max(capable) if capable else max(times)


#: registry, in gauntlet display order
SCHEMES: Dict[str, Type[Scheme]] = {
    cls.name: cls for cls in (
        HeliosScheme, SynScheme, StOnlyScheme, RandomScheme,
        AsynScheme, AfoScheme,
        ScaffoldScheme, FluidScheme, DelayedScheme,
    )
}


def make_scheme(name: str) -> Scheme:
    """Resolve a scheme name to its policy object (the engines call this
    once in ``__post_init__``; everything downstream reads the object)."""
    try:
        return SCHEMES[name]()
    except KeyError:
        raise ValueError(
            f"unknown scheme {name!r}: supported schemes are "
            f"{tuple(SCHEMES)}") from None
