"""Family adapters: the seam between model families and the FL round engines.

The round engines (federated.runtime) are family-blind: everything that
varies by model family — batch sampling and shapes (images+labels vs token
streams), the eval metric (accuracy vs cross-entropy), per-unit cycle-score
computation, and parameter-space mask expansion for masked-mean aggregation —
lives behind a :class:`FamilyAdapter`.  To federate a new family, implement
the five family hooks below and register it in :func:`make_adapter`; the
sequential and batched engines, elastic scaling, checkpointing, and the
schemes/baselines all come for free.

A family must provide:

* a ``ModelAPI`` (models.api.build) with a ``loss_fn(params, batch, cfg, rt,
  masks)`` and a ``mask_schema`` of maskable units;
* train/test data as a dict of aligned arrays whose keys match the model's
  batch dict (e.g. ``{"images", "labels"}`` or ``{"tokens"}``), indexed
  along axis 0 by example;
* an eval chunk reducer returning ``(metric_sum, weight)`` so the engines
  can evaluate the full test set in jitted chunks;
* per-unit contribution scores for a parameter delta (Eq. 1);
* unit-mask -> parameter-space mask expansion (masked-mean aggregation).

Both concrete adapters are vmap-safe: every hook that runs inside the
batched engine's round program (loss, scores, mask expansion) contains no
Python branching on traced values.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import contribution as C
from repro.core import masking as MK
from repro.models import build, default_runtime, logical_axes
from repro.models.cnn import cnn_logits

#: model families whose batch is a plain token stream {"tokens": (B, S)}
TOKEN_FAMILIES = ("dense", "moe", "ssm", "hybrid")


class FamilyAdapter:
    """Base adapter: generic example-indexed data handling + family hooks."""

    #: history/metric key ("acc" higher-is-better, "ce" lower-is-better)
    metric_name: str = "metric"
    #: True when larger metric values are better (accuracy-style)
    higher_is_better: bool = True

    def __init__(self, cfg: ModelConfig, kernels: str = "reference",
                 mask_block: int = 128):
        self.cfg = cfg
        self.api = build(cfg)
        self.axes = logical_axes(cfg)
        self.schema = self.api.mask_schema
        #: execution substrate for the soft-training loss: "reference"
        #: (plain jnp) or "pallas" (block-sparse masked matmuls + flash
        #: attention, kernels/ops.py); ``mask_block`` is the skip
        #: granularity the kernels use (match HeliosConfig.mask_block)
        self.kernels = kernels
        self.mask_block = mask_block

    # -- data ----------------------------------------------------------
    def num_examples(self, data: Dict[str, np.ndarray]) -> int:
        return len(next(iter(data.values())))

    def sample_batch(self, rng: np.random.Generator,
                     data: Dict[str, np.ndarray], idx: np.ndarray,
                     local_steps: int, batch_size: int) -> dict:
        """Draw a (local_steps, batch_size)-leading batch dict from one
        client's example indices, consuming the host RNG exactly once (the
        batched engine replays the sequential engine's draw order).

        ``idx`` may be any array-like — in particular a lazy partition view
        (data.federated.LazyParts), which only materializes indices for the
        clients actually sampled into a round's cohort.
        """
        idx = np.asarray(idx)
        take = rng.choice(idx, size=(local_steps, batch_size),
                          replace=len(idx) < local_steps * batch_size)
        return {k: jnp.asarray(v[take]) for k, v in data.items()}

    def sample_cohort(self, rng: np.random.Generator,
                      data: Dict[str, np.ndarray], idx_seq,
                      local_steps: int, batch_size: int,
                      pad_to: int = 0) -> dict:
        """Per-client batches drawn in cohort order, stacked along a leading
        client axis.

        Padding slots (up to ``pad_to``: shard-divisible cohort shapes for
        the sharded engine, power-of-two event buckets for the async
        engine) replicate the first client's draw WITHOUT consuming the
        host RNG, so the padded engines stay draw-for-draw equivalent to
        the sequential references; engines give padding slots zero
        aggregation/mixing weight.
        """
        per = [self.sample_batch(rng, data, idx, local_steps, batch_size)
               for idx in idx_seq]
        if pad_to and pad_to > len(per):
            per = per + [per[0]] * (pad_to - len(per))
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    def eval_slice(self, data: Dict[str, np.ndarray], lo: int,
                   hi: int) -> dict:
        return {k: jnp.asarray(v[lo:hi]) for k, v in data.items()}

    # -- family hooks --------------------------------------------------
    def loss_fn(self, params, batch, masks):
        """Masked training loss — traced inside the round program."""
        raise NotImplementedError

    def eval_chunk(self, params, batch):
        """(metric_sum, weight) over one test chunk — jitted by the engine."""
        raise NotImplementedError

    def cycle_scores(self, params_new, params_old):
        """Eq. 1 per-unit contribution scores of a cycle's parameter delta."""
        raise NotImplementedError

    def expand_masks(self, unit_masks, params_tree):
        """Unit masks -> params-shaped 0/1 tree (masked-mean aggregation)."""
        raise NotImplementedError

    def expand_masks_batch(self, unit_masks, params_tree):
        """``expand_masks`` over a stacked cohort (leading client axis).

        Works for any family whose ``expand_masks`` is vmap-safe, so new
        adapters get the batched aggregation path for free.
        """
        return jax.vmap(lambda um: self.expand_masks(um, params_tree))(
            unit_masks)


class CNNAdapter(FamilyAdapter):
    """Paper testbed: image classification, prefix-keyed mask schema."""

    metric_name = "acc"
    higher_is_better = True

    def loss_fn(self, params, batch, masks):
        rt = {"kernels": self.kernels, "mask_block": self.mask_block}
        return self.api.loss_fn(params, batch, self.cfg, rt, masks)

    def eval_chunk(self, params, batch):
        logits = cnn_logits(params, batch["images"], self.cfg)
        correct = jnp.sum(jnp.argmax(logits, -1) == batch["labels"])
        n = batch["labels"].shape[0]
        return correct.astype(jnp.float32), jnp.asarray(n, jnp.float32)

    def cycle_scores(self, params_new, params_old):
        return C.cnn_unit_scores(C.delta(params_new, params_old), self.schema)

    def expand_masks(self, unit_masks, params_tree):
        return MK.cnn_expand_masks(unit_masks, params_tree)


class TokenLMAdapter(FamilyAdapter):
    """Token-stream LMs (dense / moe / ssm / hybrid): axis-driven scores,
    cross-entropy eval, generic logical-axes mask expansion."""

    metric_name = "ce"
    higher_is_better = False

    def __init__(self, cfg: ModelConfig, kernels: str = "reference",
                 mask_block: int = 128):
        super().__init__(cfg, kernels, mask_block)
        self.rt = default_runtime(cfg)
        self.rt["kernels"] = kernels
        self.rt["mask_block"] = mask_block
        # eval always runs the reference substrate (matching CNNAdapter):
        # there are no masks to skip, so the kernels buy nothing — and on
        # CPU the interpret-mode flash kernel would slow every full-test-set
        # pass for free
        self.eval_rt = default_runtime(cfg)

    def loss_fn(self, params, batch, masks):
        return self.api.loss_fn(params, batch, self.cfg, self.rt, masks)

    def eval_chunk(self, params, batch):
        ce = self.api.loss_fn(params, batch, self.cfg, self.eval_rt, None)
        n = batch["tokens"].shape[0]
        return ce * n, jnp.asarray(n, jnp.float32)

    def cycle_scores(self, params_new, params_old):
        return C.unit_scores(C.delta(params_new, params_old), self.axes,
                             self.schema)

    def expand_masks(self, unit_masks, params_tree):
        return MK.expand_masks(self.axes, unit_masks, params_tree)


def make_adapter(cfg: ModelConfig, kernels: str = "reference",
                 mask_block: int = 128) -> FamilyAdapter:
    """Family dispatch for the FL engines.

    ``kernels="pallas"`` makes the adapter's loss run on the Pallas
    soft-training kernels (kernels/ops.py) — same trajectories as
    ``"reference"`` at atol 1e-5 (tests/test_kernel_softtrain.py).
    """
    if cfg.family == "cnn":
        return CNNAdapter(cfg, kernels, mask_block)
    if cfg.family in TOKEN_FAMILIES:
        return TokenLMAdapter(cfg, kernels, mask_block)
    supported = ("cnn",) + TOKEN_FAMILIES
    raise NotImplementedError(
        f"no FamilyAdapter for family {cfg.family!r} (supported families: "
        f"{supported}): encdec/vlm need extra input streams (enc_embeds / "
        "image_embeds) — subclass FamilyAdapter with a sample_batch that "
        "supplies them and register it here")
