"""Heterogeneous-device simulator (paper Table I + Fig. 1).

No real heterogeneous hardware exists in this container, so client wall time
is SIMULATED with the paper's own cost model: a client's training cycle takes

    t = T_base * speed_factor * volume

time units (soft-training FLOPs scale ~linearly in the volume P, Section
IV.C).  ``speed_factor`` values derive from Table I time costs normalized to
a capable reference device (~8.2 min/cycle), matching Fig. 1's 2.3h -> 7.7h
(~3.3x) slowdown.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.core.identification import DeviceProfile
# canonical home moved to the discrete-event core; re-exported for callers
# that still import the clock from here

#: paper Table I: 4 straggler settings running AlexNet on CIFAR-10.
#: (compute workload GFLOPS, memory usage MB, time cost min)
TABLE_I = [
    DeviceProfile("jetson-nano-cpu", compute_gflops=7.0, memory_mb=252,
                  mem_bandwidth=4_000, net_bandwidth=100, speed_factor=2.5),
    DeviceProfile("raspberry-pi", compute_gflops=6.0, memory_mb=150,
                  mem_bandwidth=2_000, net_bandwidth=100, speed_factor=2.9),
    DeviceProfile("deeplens-gpu", compute_gflops=5.5, memory_mb=100,
                  mem_bandwidth=3_000, net_bandwidth=100, speed_factor=3.3),
    DeviceProfile("deeplens-cpu", compute_gflops=4.5, memory_mb=110,
                  mem_bandwidth=2_500, net_bandwidth=100, speed_factor=4.15),
]

CAPABLE = DeviceProfile("jetson-nano-gpu", compute_gflops=25.0,
                        memory_mb=400, mem_bandwidth=8_000,
                        net_bandwidth=100, speed_factor=1.0)


def make_fleet(num_capable: int, num_stragglers: int) -> List[DeviceProfile]:
    """Paper settings: (2 capable + 2 stragglers) or (3 + 3)."""
    out = [dataclasses.replace(CAPABLE, name=f"capable-{i}")
           for i in range(num_capable)]
    for i in range(num_stragglers):
        out.append(dataclasses.replace(TABLE_I[i % len(TABLE_I)],
                                       name=f"straggler-{i}"))
    return out


def cycle_time(profile: DeviceProfile, volume: float = 1.0,
               base: float = 1.0) -> float:
    return base * profile.speed_factor * max(volume, 1e-3)
