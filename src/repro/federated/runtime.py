"""Federated round engines: Helios + the paper's four baselines (§VII.A).

  helios   — soft-training stragglers, synchronous aggregation (this paper)
  syn      — Synchronized FL: everyone trains the full model, wait for all
  asyn     — Asynchronous FL: updates mixed in on arrival, no waiting
  afo      — Asynchronous Federated Optimization (Xie et al. [6]):
             staleness-discounted mixing
  random   — Caldas et al. [12]: random sub-model of the expected volume
             each cycle (no contribution top-k, no rotation regulation)
  st_only  — Helios soft-training WITHOUT the Eq. 10 aggregation
             optimization (the §VII.C ablation)

Time is simulated (heterogeneity.cycle_time); accuracy is real (models train
on real arrays).  The sync engines are also the reference semantics for the
datacenter pjit path (launch/train.py), which fuses the same round into one
compiled program.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HeliosConfig, ModelConfig
from repro.core import aggregation as AG
from repro.core import masking as MK
from repro.core import soft_train as ST
from repro.core import volume as VOL
from repro.core.identification import (DeviceProfile, identify_resource_based,
                                       identify_time_based)
from repro.federated.heterogeneity import SimClock, cycle_time
from repro.models import build, init_params, logical_axes
from repro.models.cnn import cnn_accuracy
from repro.optim import apply_updates, make_optimizer


@dataclasses.dataclass
class Client:
    cid: int
    profile: DeviceProfile
    data_idx: np.ndarray
    volume: float = 1.0
    helios_state: Optional[dict] = None
    is_straggler: bool = False
    staleness_anchor: int = 0          # round the client last pulled from


@dataclasses.dataclass
class FLRun:
    """One engine execution: holds jitted steps + mutable server state."""

    cfg: ModelConfig
    hcfg: HeliosConfig
    scheme: str
    clients: List[Client]
    images: np.ndarray
    labels: np.ndarray
    test_images: np.ndarray
    test_labels: np.ndarray
    batch_size: int = 32
    local_steps: int = 5
    lr: float = 0.05
    seed: int = 0
    eval_batch: int = 512

    def __post_init__(self):
        self.api = build(self.cfg)
        self.axes = logical_axes(self.cfg)
        self.global_params = init_params(jax.random.PRNGKey(self.seed),
                                         self.cfg)
        self.opt = make_optimizer("momentum", self.lr)
        self.rng = np.random.default_rng(self.seed)
        self.history: List[dict] = []
        self.round = 0
        self._init_helios()
        self._jit()

    # ------------------------------------------------------------------
    def _init_helios(self):
        for c in self.clients:
            c.helios_state = ST.init_state(self.api.mask_schema,
                                           volume=c.volume, seed=c.cid)

    def _jit(self):
        cfg, api = self.cfg, self.api

        def local_train(params, batch_imgs, batch_labels, masks):
            opt_state = self.opt.init(params)

            def step(carry, b):
                p, s = carry
                imgs, labs = b

                def loss_fn(p):
                    return api.loss_fn(p, {"images": imgs, "labels": labs},
                                       cfg, None, masks)

                loss, grads = jax.value_and_grad(loss_fn)(p)
                updates, s = self.opt.update(grads, s, p, 0)
                p = apply_updates(p, updates)
                return (p, s), loss

            (params, _), losses = jax.lax.scan(step, (params, opt_state),
                                               (batch_imgs, batch_labels))
            return params, losses.mean()

        self._local_train = jax.jit(local_train)
        self._eval = jax.jit(lambda p, x, y: cnn_accuracy(p, x, y, cfg))

    # ------------------------------------------------------------------
    def _sample_batches(self, client: Client) -> tuple:
        idx = client.data_idx
        take = self.rng.choice(idx, size=(self.local_steps, self.batch_size),
                               replace=len(idx) < self.local_steps * self.batch_size)
        return self.images[take], self.labels[take]

    def _client_masks(self, client: Client) -> dict:
        if self.scheme in ("helios", "st_only", "random") and client.is_straggler:
            return client.helios_state["masks"]
        return {k: jnp.ones(s, jnp.float32)
                for k, s in self.api.mask_schema.items()}

    def _client_cycle(self, client: Client, base_params):
        """One local training cycle; returns (new_params, masks, ratio)."""
        hcfg = self.hcfg
        if self.scheme == "random" and client.is_straggler:
            # Caldas et al.: pure random selection, no top-k / rotation
            hcfg = dataclasses.replace(self.hcfg, p_s=0.0,
                                       rotation_threshold_auto=False,
                                       rotation_threshold=10 ** 9)
        if self.scheme in ("helios", "st_only", "random") and client.is_straggler:
            client.helios_state = ST.begin_cycle(client.helios_state, hcfg)
        masks = self._client_masks(client)
        imgs, labs = self._sample_batches(client)
        new_params, loss = self._local_train(base_params, imgs, labs, masks)
        if self.scheme in ("helios", "st_only") and client.is_straggler:
            scores = ST.cycle_scores(new_params, base_params, self.axes,
                                     self.api.mask_schema, family="cnn")
            client.helios_state = ST.end_cycle(client.helios_state, scores,
                                               self.hcfg)
        elif self.scheme == "random" and client.is_straggler:
            client.helios_state = ST.end_cycle(
                client.helios_state,
                client.helios_state["scores"], hcfg)
        ratio = float(MK.selected_fraction(masks))
        return new_params, masks, ratio, float(loss)

    def _aggregate(self, results):
        """results: list of (params, masks, ratio)."""
        params = [r[0] for r in results]
        ratios = [r[2] for r in results]
        if self.scheme == "helios":
            mode = self.hcfg.aggregation
        elif self.scheme in ("st_only", "random"):
            mode = "uniform"
        else:
            mode = "uniform"
        if mode == "masked_mean":
            pmasks = [MK.cnn_expand_masks(r[1], self.global_params)
                      for r in results]
            self.global_params = AG.aggregate_masked_mean(
                self.global_params, params, pmasks, ratios)
        else:
            self.global_params = AG.aggregate(mode, self.global_params,
                                              params, ratios=ratios)

    def evaluate(self) -> float:
        n = min(self.eval_batch, len(self.test_labels))
        return float(self._eval(self.global_params, self.test_images[:n],
                                self.test_labels[:n]))

    # ------------------------------------------------------------------
    # engines
    # ------------------------------------------------------------------
    def run_sync(self, rounds: int, eval_every: int = 1) -> List[dict]:
        """helios / st_only / random / syn."""
        pace = float(np.median([cycle_time(c.profile, 1.0)
                                for c in self.clients
                                if not c.is_straggler])) or 1.0
        clock = 0.0
        for r in range(rounds):
            results, times = [], []
            for c in self.clients:
                vol = c.volume if (self.scheme != "syn" and c.is_straggler) \
                    else 1.0
                t = cycle_time(c.profile, vol)
                times.append(t)
                results.append(self._client_cycle(c, self.global_params))
                # volume adaptation toward the collaboration pace (§IV.C)
                if self.scheme == "helios" and c.is_straggler and \
                        self.hcfg.adapt_volume:
                    c.volume = VOL.adapt_volume(c.volume, t, pace,
                                                self.hcfg.adapt_gain,
                                                self.hcfg.min_volume)
                    c.helios_state = ST.set_volume(c.helios_state, c.volume)
            self._aggregate(results)
            clock += max(times)
            self.round += 1
            if r % eval_every == 0 or r == rounds - 1:
                self.history.append({
                    "scheme": self.scheme, "cycle": r + 1, "time": clock,
                    "acc": self.evaluate(),
                    "loss": float(np.mean([x[3] for x in results])),
                    "volumes": [c.volume for c in self.clients]})
        return self.history

    def run_async(self, capable_cycles: int, mix_weight: float = 0.5,
                  staleness_a: float = 0.5, eval_every: int = 1) -> List[dict]:
        """asyn / afo: event-driven, no waiting for stragglers."""
        clock = SimClock()
        snapshots = {0: self.global_params}
        for c in self.clients:
            c.staleness_anchor = 0
            clock.schedule(cycle_time(c.profile, 1.0), c.cid)
        done_fast = 0
        agg_counter = 0
        by_id = {c.cid: c for c in self.clients}
        while done_fast < capable_cycles and not clock.empty():
            cid = clock.pop()
            c = by_id[cid]
            base = snapshots.get(c.staleness_anchor, self.global_params)
            new_params, _, _, loss = self._client_cycle(c, base)
            stale = agg_counter - c.staleness_anchor
            w = mix_weight
            if self.scheme == "afo":
                w = mix_weight * AG.staleness_weight(stale, staleness_a)
            self.global_params = AG.mix(self.global_params, new_params, w)
            agg_counter += 1
            snapshots[agg_counter] = self.global_params
            if len(snapshots) > 64:
                snapshots.pop(min(snapshots))
            c.staleness_anchor = agg_counter
            clock.schedule(cycle_time(c.profile, 1.0), cid)
            if not c.is_straggler:
                done_fast += 1
                if done_fast % eval_every == 0:
                    self.history.append({
                        "scheme": self.scheme, "cycle": done_fast,
                        "time": clock.now, "acc": self.evaluate(),
                        "loss": loss, "staleness": stale})
        return self.history

    # ------------------------------------------------------------------
    # elastic scalability (§VI.C)
    # ------------------------------------------------------------------
    def add_client(self, profile: DeviceProfile, data_idx: np.ndarray,
                   white_box: bool = True) -> Client:
        """New device joins mid-flight: identify -> assign volume -> admit."""
        cid = max((c.cid for c in self.clients), default=-1) + 1
        if white_box:
            times, stragglers = identify_resource_based(
                workload_gflop=100.0, memory_mb=200.0,
                devices=[c.profile for c in self.clients] + [profile])
            is_straggler = len(self.clients) in stragglers or \
                profile.speed_factor > 1.5
        else:
            sim = [cycle_time(c.profile, 1.0) for c in self.clients] + \
                [cycle_time(profile, 1.0)]
            times, stragglers = identify_time_based(
                lambda d: None, len(sim), simulated_times=sim)
            is_straggler = len(self.clients) in stragglers
        pace = float(np.median([cycle_time(c.profile, 1.0)
                                for c in self.clients if not c.is_straggler])
                     or [1.0])
        vol = VOL.volume_from_profile(cycle_time(profile, 1.0), pace,
                                      self.hcfg.min_volume) \
            if is_straggler else 1.0
        c = Client(cid=cid, profile=profile, data_idx=data_idx, volume=vol,
                   is_straggler=is_straggler)
        c.helios_state = ST.init_state(self.api.mask_schema, volume=vol,
                                       seed=cid)
        self.clients.append(c)
        return c

    def remove_client(self, cid: int) -> None:
        self.clients = [c for c in self.clients if c.cid != cid]


def setup_clients(profiles: Sequence[DeviceProfile],
                  parts: Sequence[np.ndarray],
                  hcfg: HeliosConfig,
                  identification: str = "resource") -> List[Client]:
    """Straggler identification (§IV.B) + volume targets (§IV.C)."""
    n = len(profiles)
    sim_times = [cycle_time(p, 1.0) for p in profiles]
    if identification == "resource":
        _, stragglers = identify_resource_based(
            workload_gflop=100.0, memory_mb=200.0, devices=list(profiles))
    else:
        _, stragglers = identify_time_based(lambda d: None, n,
                                            simulated_times=sim_times)
    pace = float(np.median([t for i, t in enumerate(sim_times)
                            if i not in stragglers]) or 1.0)
    clients = []
    for i, p in enumerate(profiles):
        is_s = i in stragglers
        vol = VOL.volume_from_profile(sim_times[i], pace, hcfg.min_volume) \
            if is_s else 1.0
        clients.append(Client(cid=i, profile=p, data_idx=parts[i],
                              volume=vol, is_straggler=is_s))
    return clients
