"""Federated round engines: execution strategies for any Scheme.

The ALGORITHM lives behind the pluggable policy seam in
:mod:`repro.federated.schemes` — the paper's helios / syn / st_only /
random / asyn / afo plus the published straggler baselines scaffold /
fluid / delayed.  This module owns EXECUTION only: an engine never
compares scheme strings (tests/test_schemes.py asserts that), it reads
the resolved ``self._scheme`` policy object's flags and hooks.

Time is simulated (federated.events / heterogeneity.cycle_time); the metric
is real (models train on real arrays).  The engines are FAMILY-BLIND and
SCHEME-BLIND: everything that varies by model family lives behind
federated.adapter.FamilyAdapter and everything that varies by algorithm
behind federated.schemes.Scheme, so the same engines federate the CNN
testbed and the token-stream LM families under any registered scheme.
Train/test data are dicts of aligned arrays keyed like the model's batch,
indexed along axis 0.

The engine matrix (one execution strategy per row, same semantics per
column):

  * :class:`FLRun` — the sequential reference for BOTH timing models: the
    sync loop re-dispatches ``_local_train`` per client, and ``run_async``
    processes one completion event at a time with Python-dict snapshots.
    Simple, but host dispatch caps the simulated population size.
  * :class:`AsyncFLRun` — the bucketed async engine: the deterministic
    event core (federated.events) pops buckets of near-simultaneous
    completions and each bucket runs as ONE jitted program (vmapped local
    training from a device-side snapshot ring + staleness-weighted mixing
    scan).  Same seed => same trajectory as ``FLRun.run_async``.
  * :class:`BatchedFLRun` — the batched sync engine: a whole round
    (begin_cycle -> masked training -> end_cycle -> aggregation) as one
    jitted vmapped program per cohort.  Inherits the bucketed async path.
  * :class:`ShardedFLRun` — the batched round program shard_mapped over a
    1-D ``("clients",)`` device mesh with host-resident population state.

All four sync loops share ONE host protocol — the template method
:meth:`FLRun.run_sync` (draw cohort -> pace -> times -> train -> volume
adaptation -> record); engines override the ``_train_cohort`` /
``_write_volumes`` / ``_finish_sync`` hooks, never the loop, so the
cross-engine equivalence contract is stated in exactly one place.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import checkpoint as CKPT
from repro.analysis import contracts as CT
from repro.configs.base import HeliosConfig, ModelConfig
from repro.core import aggregation as AG
from repro.core import masking as MK
from repro.core import soft_train as ST
from repro.core import volume as VOL
from repro.core.identification import (DeviceProfile, identify_resource_based,
                                       identify_time_based)
from repro.federated.adapter import FamilyAdapter, make_adapter
from repro.federated.events import (ArrivalProcess, DropoutProcess, Event,
                                    SimClock)
from repro.federated.heterogeneity import cycle_time
from repro.federated.schemes import Scheme, make_scheme
from repro.launch.mesh import make_client_mesh
from repro.models import init_params
from repro.obs import recorder as OBS
from repro.optim import apply_updates, compression as CP, make_optimizer


def _make_local_train(adapter: FamilyAdapter, opt, with_correction=False):
    """E masked local SGD steps under lax.scan — the one training loop all
    engines share (sequential jits it directly; batched/async engines vmap
    it per cohort/bucket, which keeps the engines numerically in
    lock-step).  ``batches`` is a dict pytree whose leaves carry a leading
    (local_steps,) axis.

    ``with_correction`` (SCAFFOLD schemes) adds a fixed per-client
    gradient correction ``corr = c_global - c_i`` to every step — a
    fourth argument, built only when the scheme asks so every other
    scheme's program signature is byte-identical to the pre-seam one."""

    if with_correction:
        def local_train_corr(params, batches, masks, corr):
            opt_state = opt.init(params)

            def step(carry, batch):
                p, s = carry

                def loss_fn(pp):
                    return adapter.loss_fn(pp, batch, masks)

                loss, grads = jax.value_and_grad(loss_fn)(p)
                grads = jax.tree.map(lambda g, c: g + c, grads, corr)
                updates, s = opt.update(grads, s, p, 0)
                return (apply_updates(p, updates), s), loss

            (params, _), losses = jax.lax.scan(step, (params, opt_state),
                                               batches)
            return params, losses.mean()

        return local_train_corr

    def local_train(params, batches, masks):
        opt_state = opt.init(params)

        def step(carry, batch):
            p, s = carry

            def loss_fn(pp):
                return adapter.loss_fn(pp, batch, masks)

            loss, grads = jax.value_and_grad(loss_fn)(p)
            updates, s = opt.update(grads, s, p, 0)
            return (apply_updates(p, updates), s), loss

        (params, _), losses = jax.lax.scan(step, (params, opt_state), batches)
        return params, losses.mean()

    return local_train


def _median_pace(capable_times: Sequence[float]) -> float:
    """Median capable-device cycle time, 1.0 for an all-straggler cohort.

    The explicit empty guard matters: ``np.median([])`` is NaN, and NaN is
    truthy, so ``float(np.median([...])) or 1.0`` silently kept NaN and
    poisoned the volume controller.
    """
    return float(np.median(capable_times)) if capable_times else 1.0


def _collab_pace(clients: Sequence["Client"]) -> float:
    """§IV.C collaboration pace over a client list."""
    return _median_pace([cycle_time(c.profile, 1.0) for c in clients
                         if not c.is_straggler])


@dataclasses.dataclass
class Client:
    cid: int
    profile: DeviceProfile
    data_idx: np.ndarray
    volume: float = 1.0
    helios_state: Optional[dict] = None
    is_straggler: bool = False
    staleness_anchor: int = 0          # agg step the client last pulled from


@dataclasses.dataclass
class FLRun:
    """One engine execution: holds jitted steps + mutable server state."""

    cfg: ModelConfig
    hcfg: HeliosConfig
    scheme: str
    clients: List[Client]
    train_data: Dict[str, np.ndarray]
    test_data: Dict[str, np.ndarray]
    batch_size: int = 32
    local_steps: int = 5
    lr: float = 0.05
    seed: int = 0
    eval_batch: int = 512              # eval CHUNK size (full set is scored)
    #: partial participation: sample this many clients per round (0 = all).
    #: The population's Helios state persists across rounds; only the
    #: sampled cohort trains, and §IV.C pace/volume adaptation runs over it.
    participation: int = 0
    #: cohort sampler: "uniform", or "time_weighted" (p ∝ 1/cycle_time, so
    #: fast devices are drawn more often and the round critical path drops)
    sampler: str = "uniform"
    #: async event processes (federated.events): completion-delay jitter and
    #: per-event update loss.  None = the deterministic Table-I cost model.
    #: Both engines call them once per event in pop order, so a fixed seed
    #: still gives engine-identical trajectories.
    arrival: Optional[ArrivalProcess] = None
    dropout: Optional[DropoutProcess] = None
    #: max distinct compiled programs kept per engine (round shapes, bucket
    #: shapes); least-recently-used programs are evicted beyond this
    round_cache_cap: int = 8
    #: soft-training execution substrate: "reference" (plain jnp masked ops)
    #: or "pallas" (block-sparse masked-matmul + flash-attention kernels,
    #: kernels/ops.py — interpret mode on CPU, native on TPU).  Every engine
    #: (seq/batched/sharded/async) accepts both and produces the same
    #: trajectory at atol 1e-5 (tests/test_kernel_softtrain.py).
    kernels: str = "reference"
    #: kernel skip granularity.  0 (default) = follow HeliosConfig.
    #: mask_block (falling back to 128 when that is 0 too), so block-
    #: granular Eq. 2 selection and the kernels' skip blocks stay in sync
    #: from the ONE knob; set explicitly only to decouple them.
    mask_block: int = 0
    #: uplink compression — the comms/memory twin of ``kernels``, threaded
    #: through every engine the same way.  "none" keeps today's exact
    #: trajectories; the lossy modes compress each simulated
    #: client->server delta at the aggregation boundary with per-client
    #: error feedback (optim.compression, host-resident accumulators)
    #: masked by the Eq. 2 masks: "topk" (top-``comp_frac`` coords, fp16
    #: values), "quant" (dense int-``comp_bits``), "delta" (top-k +
    #: int-``comp_bits`` values).  quant/delta additionally switch the
    #: async snapshot ring to the matching lossy anchor store.
    compression: str = "none"
    comp_frac: float = 0.05
    comp_bits: int = 8
    #: async ring freshness window: anchors staler than this many
    #: aggregation steps decode from the int ring rows; fresher ones read
    #: a small rotating full-precision buffer (exact)
    comp_fresh: int = 8
    #: DGC-style compression warmup: the first ``comp_warmup`` SYNC rounds
    #: upload dense (bit-identical to ``compression="none"``) before the
    #: lossy codec kicks in — closes the documented topk/delta early-round
    #: convergence gap.  Counts global ``self.round``s; the async event
    #: loops have no round index and always compress.
    comp_warmup: int = 0
    #: telemetry recorder (repro.obs).  None builds a fresh one, armed
    #: only when ``REPRO_OBS=on``; pass one to arm explicitly or to share
    #: a sink across runs.  Every legacy engine counter
    #: (``uplink_updates``, ``events_processed``, ``agg_counter``, …) is
    #: a read-only property view onto it.
    recorder: Optional[OBS.Recorder] = None
    #: serve-while-you-train publish seam: when set, every
    #: ``publish_every``-th sync round writes the global params to this
    #: directory as an atomic checkpoint (repro.checkpoint: tmp write +
    #: fsync + os.replace) with ``{"round", "sim_time", "scheme"}``
    #: metadata, keep-``publish_keep`` GC'd.  A ``launch.serve.ServeLoop``
    #: polling the directory hot-swaps onto each publish; atomicity means
    #: it can never observe a partial snapshot.
    publish_dir: Optional[str] = None
    publish_every: int = 1
    publish_keep: int = 3

    def __post_init__(self):
        #: the resolved algorithm policy — every scheme decision in the
        #: engines reads this object (never the raw string again)
        self._scheme: Scheme = make_scheme(self.scheme)
        self.mask_block = self.mask_block or self.hcfg.mask_block or 128
        self.adapter = make_adapter(self.cfg, self.kernels, self.mask_block)
        self.api = self.adapter.api
        self.axes = self.adapter.axes
        self.global_params = init_params(jax.random.PRNGKey(self.seed),
                                         self.cfg)
        self.opt = make_optimizer("momentum", self.lr)
        self.rng = np.random.default_rng(self.seed)
        # participation draws live on their OWN stream: every engine
        # (sequential / batched / sharded) reconstructs the identical
        # schedule from the seed, and full-participation runs stay
        # draw-for-draw unchanged when sampling is off
        self.sample_rng = np.random.default_rng((self.seed, 0x5EED))
        self.cohort_log: List[List[int]] = []
        self.history: List[dict] = []
        self.round = 0
        if self.compression not in CP.MODES:
            raise ValueError(f"compression must be one of {CP.MODES}, "
                             f"got {self.compression!r}")
        if self.comp_fresh < 1:
            raise ValueError("comp_fresh must be >= 1 (the ring keeps at "
                             "least the newest anchor full-precision)")
        if self.comp_warmup < 0:
            raise ValueError("comp_warmup must be >= 0")
        if self.publish_every < 1:
            raise ValueError("publish_every must be >= 1")
        self._comp_total, self._comp_leaves = \
            CP.param_census(self.global_params)
        #: the unified accounting surface (repro.obs): uplink/downlink
        #: update counts are host-int recorder counters, ``uplink_coords``
        #: a DEVICE scalar accumulated eagerly (no host sync in the hot
        #: loops; converted once in :meth:`uplink_bytes`).
        #: ``uplink_dense_updates`` counts the warmup-round updates that
        #: bypassed the codec; ``uplink_extra_updates`` the scheme's dense
        #: side-channel (SCAFFOLD control deltas).
        self.rec = self.recorder if self.recorder is not None \
            else OBS.Recorder()
        self.rec.accum("uplink_coords", jnp.float32(0.0))
        if self.compression != "none":
            self._err_store = CP.HostErrorStore(self.global_params)
        self._init_helios()
        self._jit()
        self._scheme.init_run(self)
        if self.rec.armed:                 # manifest is an emission-side
            self.rec.manifest.update(self._obs_manifest())

    # ------------------------------------------------------------------
    def _init_helios(self):
        for c in self.clients:
            c.helios_state = ST.init_state(self.adapter.schema,
                                           volume=c.volume, seed=c.cid)

    def _jit(self):
        self._local_train = jax.jit(_make_local_train(
            self.adapter, self.opt, self._scheme.uses_control))
        self._eval_chunk = jax.jit(self.adapter.eval_chunk)
        if self.compression != "none":
            mode, frac, bits = self.compression, self.comp_frac, \
                self.comp_bits

            def compress_one(base, new_params, err, pmasks):
                delta = jax.tree.map(
                    lambda n, b: n.astype(jnp.float32)
                    - b.astype(jnp.float32), new_params, base)
                sent, new_err, coords = CP.compress_update(
                    delta, err, mode, frac, bits, pmasks)
                hat = jax.tree.map(
                    lambda b, s: (b.astype(jnp.float32) + s).astype(b.dtype),
                    base, sent)
                return hat, new_err, coords

            # the sequential engines' per-update codec (batched/sharded/
            # bucketed engines trace the same math inside their programs)
            self._compress_one = jax.jit(compress_one)

    # ------------------------------------------------------------------
    def _ring_mode(self) -> str:
        """Snapshot-ring anchor precision keyed off the uplink knob:
        quant/delta compress the ring the matching way; none/topk keep the
        exact fp32 store (top-k has no dense-anchor analogue)."""
        return self.compression \
            if self.compression in ("quant", "delta") else "fp32"

    def uplink_bytes(self) -> float:
        """Total simulated client->server wire bytes so far.

        Syncs ``uplink_coords`` once — call from benches/tests, never a
        hot loop.  ``none`` moves every param dense-f32 per update; the
        lossy formulas live in :func:`repro.optim.compression.uplink_bytes`.
        Warmup-round updates and scheme side-channels (SCAFFOLD control
        deltas) are billed dense.
        """
        dense = float(self.uplink_extra_updates) * self._comp_total * 4.0
        if self.compression == "none":
            return dense + float(self.uplink_updates) * self._comp_total * 4.0
        coords = self.rec.accum_value("uplink_coords")
        comp_updates = self.uplink_updates - self.uplink_dense_updates
        return (dense
                + float(self.uplink_dense_updates) * self._comp_total * 4.0
                + CP.uplink_bytes(self.compression, coords, self._comp_total,
                                  self._comp_leaves * comp_updates,
                                  self.comp_bits))

    def downlink_bytes(self) -> float:
        """Total simulated server->client broadcast bytes so far — the
        accounting twin of :meth:`uplink_bytes` (PR 7 modeled only the
        uplink).  Every participating update (sync cohort member,
        processed async event) pulls the dense fp32 global; downlink
        compression is not modeled, so this is pure host arithmetic."""
        return float(self.downlink_updates) * self._comp_total * 4.0

    # -- legacy counter views (the recorder is the single surface) -------
    @property
    def uplink_updates(self) -> int:
        return self.rec.count("uplink_updates")

    @property
    def uplink_dense_updates(self) -> int:
        return self.rec.count("uplink_dense_updates")

    @property
    def uplink_extra_updates(self) -> int:
        return self.rec.count("uplink_extra_updates")

    @property
    def uplink_coords(self):
        return self.rec.accum_raw("uplink_coords", jnp.float32(0.0))

    @property
    def downlink_updates(self) -> int:
        return self.rec.count("downlink_updates")

    @property
    def events_processed(self) -> int:
        return self.rec.count("events_processed")

    @property
    def events_dropped(self) -> int:
        return self.rec.count("events_dropped")

    @property
    def agg_counter(self) -> int:
        return self.rec.count("agg_counter")

    @property
    def snapshot_peak(self) -> int:
        return self.rec.count("snapshot_peak", 1)

    @property
    def snapshot_anchor_misses(self) -> int:
        return self.rec.count("snapshot_anchor_misses")

    # -- telemetry ------------------------------------------------------
    def _obs_manifest(self) -> dict:
        """Run-identifying manifest for the telemetry sinks: engine,
        scheme (with its full flag census), family, the kernel and
        compression knobs, population shape, seeds, and the git sha."""
        return {"engine": type(self).__name__,
                "scheme": self.scheme,
                "scheme_flags": self._scheme.manifest(),
                "family": self.cfg.family,
                "model": self.cfg.name,
                "kernels": self.kernels,
                "mask_block": self.mask_block,
                "compression": self.compression,
                "comp_frac": self.comp_frac,
                "comp_bits": self.comp_bits,
                "comp_warmup": self.comp_warmup,
                "clients": len(self.clients),
                "participation": self.participation,
                "sampler": self.sampler,
                "local_steps": self.local_steps,
                "batch_size": self.batch_size,
                "lr": self.lr,
                "seed": self.seed,
                "git_sha": OBS.git_sha()}

    def _obs_finish(self, seam: str) -> None:
        """End-of-run telemetry (armed only — a disarmed run does zero
        extra work and zero extra host transfers here): final byte
        gauges, the error-store census, and the contracts bridge
        (compile report + contract counters), so a flushed run log is
        self-contained."""
        if not self.rec.armed:
            return
        self.rec.gauge("uplink_mb", self.uplink_bytes() / 1e6)
        self.rec.gauge("downlink_mb", self.downlink_bytes() / 1e6)
        if self.compression != "none":
            self.rec.event("error_store", seam=seam,
                           **self._err_store.stats())
        CT.emit_obs(self, self.rec)

    def _comp_active(self) -> bool:
        """Whether THIS sync round's uplink goes through the lossy codec
        (False during the first ``comp_warmup`` rounds — those run the
        exact same program a ``compression="none"`` run compiles, so the
        warmup prefix is bit-identical to an uncompressed run)."""
        return self.compression != "none" and self.round >= self.comp_warmup

    def _get_cached_program(self, key, builder):
        """LRU of compiled programs; elastic churn (or per-draw cohort /
        bucket shapes) returning to a recently-seen key pays no recompile,
        and keys beyond ``round_cache_cap`` are evicted."""
        if not hasattr(self, "_round_cache"):
            self._round_cache = OrderedDict()
        if key in self._round_cache:
            self._round_cache.move_to_end(key)
        else:
            self._round_cache[key] = builder()
            while len(self._round_cache) > self.round_cache_cap:
                self._round_cache.popitem(last=False)
        return self._round_cache[key]

    # ------------------------------------------------------------------
    def _sample_batches(self, client: Client) -> dict:
        return self.adapter.sample_batch(self.rng, self.train_data,
                                         client.data_idx, self.local_steps,
                                         self.batch_size)

    def _client_masks(self, client: Client) -> dict:
        if self._scheme.soft_training and client.is_straggler:
            return client.helios_state["masks"]
        return ST.full_masks(self.adapter.schema)

    def _client_cycle(self, client: Client, base_params):
        """One local training cycle; returns (new_params, masks, ratio)."""
        sch = self._scheme
        soft = sch.soft_training and client.is_straggler
        hcfg = sch.effective_hcfg(self.hcfg)
        if soft:
            client.helios_state = ST.begin_cycle(client.helios_state, hcfg)
        masks = self._client_masks(client)
        batches = self._sample_batches(client)
        if sch.uses_control:
            corr = jax.tree.map(lambda cg, ci: cg - jnp.asarray(ci),
                                self._c_global,
                                self._ctrl_store.row(client.cid))
            new_params, loss = self._local_train(base_params, batches,
                                                 masks, corr)
            # option-II control update from the RAW trained params (before
            # any uplink codec): dc = (x - y)/(K*lr) - c_global
            inv = 1.0 / (self.local_steps * self.lr)
            dc = jax.tree.map(
                lambda b, y, cg: (b.astype(jnp.float32)
                                  - y.astype(jnp.float32)) * inv - cg,
                base_params, new_params, self._c_global)
            self._ctrl_store.set_row(
                client.cid,
                jax.tree.map(lambda ci, d: jnp.asarray(ci, jnp.float32) + d,
                             self._ctrl_store.row(client.cid), dc))
            self._dc_buf.append(dc)
        else:
            new_params, loss = self._local_train(base_params, batches, masks)
        if soft:
            if sch.use_delta_scores:
                scores = self.adapter.cycle_scores(new_params, base_params)
            else:                                          # random [12]
                scores = client.helios_state["scores"]
            client.helios_state = ST.end_cycle(client.helios_state, scores,
                                               hcfg)
        # device scalars on purpose: the hot loops never sync on these —
        # they are converted behind the eval gate (_record_round / history)
        ratio = MK.selected_fraction(masks)
        return new_params, masks, ratio, loss

    def _apply_control(self) -> None:
        """Fold buffered client control deltas into the server control —
        after the cohort in sync rounds (all clients corrected by the
        round-start c_global, the SCAFFOLD parallel semantics), after each
        event in the async fallback."""
        if not self._dc_buf:
            return
        n = float(len(self.clients))
        for dc in self._dc_buf:
            self._c_global = jax.tree.map(lambda c, d: c + d / n,
                                          self._c_global, dc)
        self._dc_buf = []

    def _aggregate(self, results):
        """results: list of (params, masks, ratio)."""
        params = [r[0] for r in results]
        ratios = [r[2] for r in results]
        mode = self._scheme.agg_mode(self.hcfg)
        if mode == "masked_mean":
            pmasks = [self.adapter.expand_masks(r[1], self.global_params)
                      for r in results]
            self.global_params = AG.aggregate_masked_mean(
                self.global_params, params, pmasks, ratios)
        else:
            self.global_params = AG.aggregate(mode, self.global_params,
                                              params, ratios=ratios)

    def evaluate(self) -> float:
        """Full-test-set metric in jitted chunks of ``eval_batch``.

        A weighted mean over chunks, so the reported number is never a
        fixed-subset estimate (the last ragged chunk pays one extra compile).
        """
        n = self.adapter.num_examples(self.test_data)
        total = weight = 0.0
        for lo in range(0, n, self.eval_batch):
            chunk = self.adapter.eval_slice(self.test_data, lo,
                                            min(lo + self.eval_batch, n))
            s, w = self._eval_chunk(self.global_params, chunk)
            # evaluate() IS the deliberate sync point (callers gate on
            # eval_every); the per-chunk sync is intended
            total += float(s)     # repro: noqa[R3]
            weight += float(w)    # repro: noqa[R3]
        return total / max(weight, 1e-9)

    # ------------------------------------------------------------------
    # shared per-round host protocol (the sync template method)
    # ------------------------------------------------------------------
    def _draw_cohort(self) -> List[int]:
        """This round's participant indices (sorted, duplicate-free).

        Full participation returns every client.  Sampling consumes ONE
        ``sample_rng`` draw per round, so for a fixed seed every engine
        reproduces the identical participant schedule.  ``time_weighted``
        weights clients by inverse simulated cycle time at their CURRENT
        volume — all engines evolve volumes with the same host arithmetic,
        so the weights (and draws) also agree bit-for-bit.
        """
        n = len(self.clients)
        k = self.participation
        if not k or k >= n:
            return list(range(n))
        if self.sampler == "uniform":
            p = None
        elif self.sampler == "time_weighted":
            # the weights ARE _round_times over the fleet — one expression,
            # one scheme hook (Scheme.effective_volume), so the sampler and
            # the round clock can never disagree on what a straggler costs
            # (the pre-seam code duplicated the volume conditional here and
            # relied on keeping the two copies mirrored by hand)
            t = np.asarray(self._round_times())
            w = 1.0 / np.maximum(t, 1e-9)
            p = w / w.sum()
        else:
            raise ValueError(f"unknown sampler {self.sampler!r}")
        idx = self.sample_rng.choice(n, size=k, replace=False, p=p)
        return sorted(int(i) for i in idx)

    def _round_times(self, clients: Optional[Sequence["Client"]] = None) \
            -> List[float]:
        """Simulated wall time per client for one round, billed at the
        scheme's effective volume (full-model schemes never see the
        soft-training volumes)."""
        return [cycle_time(c.profile, self._scheme.effective_volume(c))
                for c in (self.clients if clients is None else clients)]

    def _record_round(self, r: int, rounds: int, eval_every: int,
                      clock: float, losses, ratios):
        """History bookkeeping shared by all sync engines; eval_every=0
        disables evaluation/history entirely (pure-throughput benchmarks).
        Takes the raw per-client losses/ratios (device scalars or arrays)
        and converts to host floats HERE, behind the eval gate — the
        run_sync hot loop itself never forces a device->host sync."""
        if eval_every > 0 and (r % eval_every == 0 or r == rounds - 1):
            self.history.append({
                "scheme": self.scheme, "cycle": r + 1, "time": clock,
                "record_cadence": "round",
                self.adapter.metric_name: self.evaluate(),
                "loss": float(np.mean(np.asarray(losses))),
                "ratios": [float(x) for x in np.asarray(ratios)],
                "volumes": [c.volume for c in self.clients],
                "downlink_mb": self.downlink_bytes() / 1e6})
            row = self.history[-1]
            self.rec.event("history", sim=row["time"],
                           **{k: v for k, v in row.items() if k != "time"})

    def run_sync(self, rounds: int, eval_every: int = 1) -> List[dict]:
        """The ONE sync host loop (every scheme with async_native=False).

        Template method: every engine runs this exact per-round protocol
        (draw cohort -> §IV.C pace -> simulated times -> scheme round_start
        -> engine-specific ``_train_cohort`` -> volume adaptation -> scheme
        round_end -> clock/record) and only overrides the hooks.  Each
        round trains only the drawn cohort (everyone under full
        participation); unsampled clients keep their Helios state
        untouched.  The pace is computed over the sampled cohort — at full
        participation it equals the whole-fleet pace, so sampling off
        reproduces the original trajectory exactly.
        """
        clock = 0.0
        for r in range(rounds):
            cohort = self._draw_cohort()
            self.cohort_log.append(cohort)
            cclients = [self.clients[i] for i in cohort]
            pace = _collab_pace(cclients)
            times = self._round_times(cclients)
            self.rec.inc("downlink_updates", len(cohort))   # global broadcast
            with self.rec.span("scheme.round_start", sim=clock, round=r):
                self._scheme.round_start(self)
            # contract: the round's device work never syncs to host —
            # losses/ratios stay device values until _record_round's gate
            with self.rec.maybe_profile(r), \
                    self.rec.span("train_cohort", sim=clock, round=r), \
                    CT.no_host_transfers("run_sync[" + self.scheme + "]"):
                losses, ratios = self._train_cohort(cohort, cclients)
            self.rec.inc("uplink_updates", len(cohort))
            if self.compression != "none" and not self._comp_active():
                self.rec.inc("uplink_dense_updates", len(cohort))  # warmup
            self.rec.inc("uplink_extra_updates",
                         len(cohort) * self._scheme.extra_dense_uplink)
            CT.assert_finite(self.global_params, tag="run_sync.global_params")
            self._adapt_volumes(cohort, cclients, times, pace)
            with self.rec.span("scheme.round_end", sim=clock, round=r):
                self._scheme.round_end(self)
            dur = self._scheme.round_duration(times, cclients)
            clock += dur
            self.round += 1
            self.rec.event("round", sim=clock, round=r, cohort=len(cohort),
                           pace=pace, duration=dur)
            self.rec.event("volumes", sim=clock, round=r,
                           volumes=[self._scheme.effective_volume(c)
                                    for c in cclients if c.is_straggler])
            if self.publish_dir and (r + 1) % self.publish_every == 0:
                self._publish_round(r, clock)
            self._record_round(r, rounds, eval_every, clock, losses, ratios)
        self._finish_sync()
        if CT.enabled():
            # one compiled program per seam per shape signature, and every
            # surviving straggler mask still satisfies the Eq. 2 structure
            CT.check_compile_budget(self, tag="run_sync.compile")
            for masks in self._contract_state_masks():
                CT.check_mask_invariants(
                    masks, block=self.hcfg.mask_block, tag="run_sync.masks")
        self._obs_finish("run_sync")   # after the walls: counters complete
        return self.history

    # -- engine hooks ---------------------------------------------------
    def _train_cohort(self, cohort: List[int], cclients: List[Client]):
        """Train the drawn cohort against the current global params and
        aggregate; returns per-client (losses, ratios) in cohort order.
        The sequential reference: one re-dispatched ``_local_train`` per
        client, consuming ``self.rng`` in cohort order (the draw order
        every other engine replays)."""
        sch = self._scheme
        results = []
        for c in cclients:
            stale = sch.uses_stale_base and c.is_straggler
            base = self._stale_base if stale else self.global_params
            r = self._client_cycle(c, base)
            if stale:
                # delayed-gradient hybrid: virtualize the stale-base update
                # onto the CURRENT global with the staleness discount, so
                # it rides the normal aggregation (and the uplink codec
                # compresses p_virtual - global like any other delta)
                disc = self._stale_disc
                p = jax.tree.map(
                    lambda g, y, b: (g.astype(jnp.float32)
                                     + disc * (y.astype(jnp.float32)
                                               - b.astype(jnp.float32))
                                     ).astype(g.dtype),
                    self.global_params, r[0], base)
                r = (p,) + r[1:]
            results.append(r)
        if sch.uses_control:
            self._apply_control()
        if self._comp_active():
            results = self._compress_results(cclients, results)
        self._aggregate(results)
        return [x[3] for x in results], [x[2] for x in results]

    def _compress_results(self, cclients: List[Client], results):
        """Lossy uplink for the sequential reference: replace each raw
        new-params with the decoded compressed update (base + sent),
        folding the un-sent residual into the client's error accumulator.
        Eq. 2 masks gate the encoder, so frozen coordinates are never
        sent (their residual survives until rotation wakes them)."""
        base = self.global_params
        out = []
        for c, r in zip(cclients, results):
            pmasks = self.adapter.expand_masks(r[1], base)
            hat, new_err, coords = self._compress_one(
                base, r[0], self._err_store.row(c.cid), pmasks)
            self._err_store.set_row(c.cid, new_err)
            self.rec.accum("uplink_coords", coords)
            out.append((hat,) + r[1:])
        return out

    def _adapt_volumes(self, cohort: List[int], cclients: List[Client],
                       times: List[float], pace: float) -> None:
        """Volume adaptation toward the collaboration pace (§IV.C) — host
        arithmetic shared verbatim by every engine; only the state
        write-back (``_write_volumes``) is engine-specific."""
        if not (self._scheme.adapt_volume and self.hcfg.adapt_volume):
            return
        upd = [j for j, c in enumerate(cclients) if c.is_straggler]
        for j in upd:
            c = cclients[j]
            c.volume = VOL.adapt_volume(c.volume, times[j], pace,
                                        self.hcfg.adapt_gain,
                                        self.hcfg.min_volume)
        if upd:
            self._write_volumes(cohort, cclients, upd)

    def _write_volumes(self, cohort: List[int], cclients: List[Client],
                       upd: List[int]) -> None:
        for j in upd:
            cclients[j].helios_state = ST.set_volume(
                cclients[j].helios_state, cclients[j].volume)

    def _finish_sync(self) -> None:
        pass

    def _publish_round(self, r: int, clock: float) -> None:
        """Round-end publish seam (serve-while-you-train): snapshot the
        current global params atomically so a concurrently-polling
        ``ServeLoop`` can hot-swap onto it.  Shared verbatim by every
        engine that runs the ``run_sync`` template."""
        with self.rec.span("publish", sim=clock, round=r):
            CKPT.save(self.publish_dir, self.round, self.global_params,
                      keep=self.publish_keep,
                      metadata={"round": self.round, "sim_time": clock,
                                "scheme": self.scheme})
        self.rec.inc("published_snapshots")
        self.rec.event("publish", sim=clock, round=r, step=self.round)

    def _contract_state_masks(self):
        """Mask trees the post-run contract sweep validates (structure
        only: 0/1 and block-constant; the count check needs the
        selection-time volume and runs in soft_train.begin_cycle's
        contract instead).  Engines that keep state elsewhere override."""
        return [c.helios_state["masks"] for c in self.clients
                if c.is_straggler and isinstance(c.helios_state, dict)
                and "masks" in c.helios_state]

    # ------------------------------------------------------------------
    # async (event-driven) reference engine
    # ------------------------------------------------------------------
    def _next_delay(self, client: Client) -> float:
        """Delay until this client's next completion — the Table-I cost
        model, optionally perturbed by the pluggable arrival process."""
        base = cycle_time(client.profile, 1.0)
        return self.arrival.delay(client.cid, base) if self.arrival else base

    def _reset_async_processes(self) -> None:
        for p in (self.arrival, self.dropout):
            if p is not None:
                p.reset(self.seed)

    def run_async(self, capable_cycles: int, mix_weight: float = 0.5,
                  staleness_a: float = 0.5, eval_every: int = 1,
                  snapshot_cap: int = 64) -> List[dict]:
        """asyn / afo reference: one un-jitted client cycle per completion
        event, Python-dict snapshots.  :class:`AsyncFLRun` reproduces this
        trajectory with bucketed device execution."""
        clock = SimClock()
        self._reset_async_processes()
        snapshots = {0: self.global_params}
        # lossy-ring reference semantics: snapshots stay full precision in
        # the dict, but an anchor read past the freshness window decodes
        # through the SAME quantize->dequantize the bucketed ring's rows
        # pay at write time (bit-identical, deterministic)
        ring_mode = self._ring_mode()
        ring_ref = jax.tree.map(lambda x: x.astype(jnp.float32),
                                self.global_params) \
            if ring_mode == "delta" else None
        # bookkeeping exposed for tests/monitoring: the snapshot dict must
        # stay bounded by cap + len(clients) and never evict a live anchor
        self.rec.set("snapshot_peak", 1)
        self.rec.set("snapshot_anchor_misses", 0)
        self.rec.set("events_processed", 0)
        self.rec.set("events_dropped", 0)
        for c in self.clients:
            c.staleness_anchor = 0
            clock.schedule(self._next_delay(c), c.cid)
        done_fast = 0
        agg_counter = 0
        by_id = {c.cid: c for c in self.clients}
        while done_fast < capable_cycles and not clock.empty():
            cid = clock.pop()
            c = by_id[cid]
            if self.dropout is not None and self.dropout.drops(cid):
                self.rec.inc("events_dropped")
                self.rec.event("drop", sim=clock.now, cid=cid)
                clock.schedule(self._next_delay(c) * self.dropout.penalty,
                               cid)
                continue
            # anchors are never evicted (below), so this lookup cannot fall
            # back to the current global params and mislabel staleness
            base = snapshots[c.staleness_anchor]
            stale = agg_counter - c.staleness_anchor
            self.rec.event("completion", sim=clock.now, cid=cid, stale=stale)
            self.rec.observe("staleness", stale)
            CT.check_staleness([stale], a=staleness_a, tag="run_async[seq]")
            with CT.no_host_transfers("run_async[seq]"):
                if ring_mode != "fp32" and stale >= self.comp_fresh:
                    base = AG.lossy_roundtrip(base, ring_ref, self.comp_bits)
                new_params, masks_u, _, loss = self._client_cycle(c, base)
                if self.compression != "none":
                    pmasks = self.adapter.expand_masks(masks_u, base)
                    new_params, new_err, coords = self._compress_one(
                        base, new_params, self._err_store.row(c.cid), pmasks)
                    self._err_store.set_row(c.cid, new_err)
                    self.rec.accum("uplink_coords", coords)
                self.rec.inc("uplink_updates")
                self.rec.inc("uplink_extra_updates",
                             self._scheme.extra_dense_uplink)
                w = self._scheme.async_weight(mix_weight, stale, staleness_a)
                self.global_params = AG.mix(self.global_params, new_params, w)
                if self._scheme.uses_control:
                    self._apply_control()      # per event: async semantics
            agg_counter += 1
            snapshots[agg_counter] = self.global_params
            c.staleness_anchor = agg_counter
            if len(snapshots) > snapshot_cap:
                # evict oldest-first, but only snapshots no live client is
                # anchored to — a slow straggler keeps its base alive, so
                # the dict is bounded by snapshot_cap + len(clients)
                anchored = {cl.staleness_anchor for cl in self.clients}
                for k in sorted(snapshots):
                    if len(snapshots) <= snapshot_cap:
                        break
                    if k != agg_counter and k not in anchored:
                        del snapshots[k]
                # eviction is the only step that could drop an anchor, so
                # the invariant check stays off the no-eviction fast path
                self.rec.inc("snapshot_anchor_misses", sum(
                    cl.staleness_anchor not in snapshots
                    for cl in self.clients))
            self.rec.set_max("snapshot_peak", len(snapshots))
            clock.schedule(self._next_delay(c), cid)
            self.rec.inc("events_processed")
            self.rec.inc("downlink_updates")   # the event's snapshot pull
            self.rec.observe("queue_depth", len(clock))
            if not c.is_straggler:
                done_fast += 1
                if eval_every > 0 and done_fast % eval_every == 0:
                    self.history.append({
                        "scheme": self.scheme, "cycle": done_fast,
                        "time": clock.now,
                        "record_cadence": "event",
                        self.adapter.metric_name: self.evaluate(),
                        # behind the eval gate: evaluate() just synced
                        "loss": float(loss),  # repro: noqa[R3]
                        "staleness": stale,
                        "downlink_mb": self.downlink_bytes() / 1e6})
                    row = self.history[-1]
                    self.rec.event("history", sim=row["time"],
                                   **{k: v for k, v in row.items()
                                      if k != "time"})
        self.rec.set("agg_counter", agg_counter)
        self.rec.set("queue_peak", clock.peak_depth)
        CT.check_snapshot_bound(self.snapshot_peak,
                                self.snapshot_anchor_misses,
                                snapshot_cap, len(self.clients),
                                tag="run_async[seq].snapshots")
        self._obs_finish("run_async[seq]")
        return self.history

    # ------------------------------------------------------------------
    # elastic scalability (§VI.C)
    # ------------------------------------------------------------------
    def add_client(self, profile: DeviceProfile, data_idx: np.ndarray,
                   white_box: bool = True) -> Client:
        """New device joins mid-flight: identify -> assign volume -> admit."""
        cid = max((c.cid for c in self.clients), default=-1) + 1
        if white_box:
            times, stragglers = identify_resource_based(
                workload_gflop=100.0, memory_mb=200.0,
                devices=[c.profile for c in self.clients] + [profile])
            is_straggler = len(self.clients) in stragglers or \
                profile.speed_factor > 1.5
        else:
            sim = [cycle_time(c.profile, 1.0) for c in self.clients] + \
                [cycle_time(profile, 1.0)]
            times, stragglers = identify_time_based(
                lambda d: None, len(sim), simulated_times=sim)
            is_straggler = len(self.clients) in stragglers
        pace = _collab_pace(self.clients)
        vol = VOL.volume_from_profile(cycle_time(profile, 1.0), pace,
                                      self.hcfg.min_volume) \
            if is_straggler else 1.0
        c = Client(cid=cid, profile=profile, data_idx=data_idx, volume=vol,
                   is_straggler=is_straggler)
        c.helios_state = ST.init_state(self.adapter.schema, volume=vol,
                                       seed=cid)
        self.clients.append(c)
        return c

    def remove_client(self, cid: int) -> None:
        self.clients = [c for c in self.clients if c.cid != cid]


@dataclasses.dataclass
class AsyncFLRun(FLRun):
    """Bucketed event-driven engine for the async schemes (asyn / afo).

    The sequential ``run_async`` dispatches one un-jitted client cycle per
    completion event from a Python dict of full-model snapshots — host
    overhead O(events), which caps the population size the simulator can
    reach.  This engine keeps the event semantics bit-compatible but
    executes them in bulk:

    * the deterministic event core (:class:`federated.events.SimClock`)
      pops a BUCKET of near-simultaneous completions per step (with the
      default ``bucket_horizon=0.0`` a bucket is exactly one equal-time
      tie-group, which provably cannot reorder events vs. the sequential
      loop — a client's next completion is strictly later than its
      current one);
    * every client in the bucket trains from its own anchor snapshot, read
      as a traced gather out of a device-side stacked **snapshot ring
      buffer** (:class:`core.aggregation.SnapshotRing`) — anchors predate
      the bucket, so the whole bucket's local training runs under ONE
      ``jax.vmap``;
    * the per-event mixing θ ← (1-w)θ + w θ_c (staleness-discounted for
      afo) folds over the bucket in event order inside the same program
      (:func:`core.aggregation.mix_bucket_ring`), writing each post-mix
      global into the ring slot the completing client re-anchors to;
    * buckets are padded to the next power of two (padding replicates slot
      0's batch without consuming host RNG, mixes at weight 0, and writes
      to the ring's scratch row), so at most log2(max_bucket)+1 programs
      are ever compiled — one per bucket-shape signature.

    Batch draws, arrival/dropout process draws, snapshot anchoring, and
    mixing order all replay the sequential reference exactly: for a fixed
    seed the two engines produce the same GLOBAL-PARAM trajectory up to
    vmapped-reduction float error (tests/test_async_engine.py).  History
    is the one deliberate divergence: the sequential loop records at every
    eval_every-th capable completion (possibly mid-tie-group), while this
    engine records at most once per bucket, after the bucket's mixes.
    """

    #: bucket events within this much virtual time of the earliest pending
    #: one.  0.0 = exact tie-groups (sequential-equivalent); > 0 trades
    #: exactness for bigger buckets (the clock advances per bucket).
    bucket_horizon: float = 0.0
    #: cap on events per bucket (bounds the vmapped program's memory)
    max_bucket: int = 128

    def _make_bucket_fn(self, bpad: int):
        adapter, opt = self.adapter, self.opt
        ones_masks = ST.full_masks(adapter.schema)
        local_train = _make_local_train(adapter, opt)
        discount = self._scheme.staleness_discount
        comp, frac, bits = self.compression, self.comp_frac, self.comp_bits
        ring_mode = self._ring_mode()

        if comp == "none":
            def bucket_fn(global_params, ring_params, base_slots,
                          write_slots, batches, stale, valid, mix_w,
                          stale_a):
                base = jax.tree.map(
                    lambda x: jnp.take(x, base_slots, axis=0), ring_params)
                trained, losses = jax.vmap(
                    lambda bp, b: local_train(bp, b, ones_masks))(base,
                                                                  batches)
                w = jnp.full((bpad,), 1.0, jnp.float32) * mix_w
                if discount:
                    w = w * AG.staleness_weights(stale, stale_a)
                w = w * valid
                new_global, new_ring = AG.mix_bucket_ring(
                    global_params, ring_params, write_slots, trained, w)
                return new_global, new_ring, losses

            return bucket_fn

        def bucket_fn(global_params, ring_state, ref, err, base_slots,
                      write_slots, fresh_read, fresh_write, is_fresh,
                      batches, stale, valid, mix_w, stale_a):
            """Compressed bucket: decode anchors (lossy ring), train,
            compress deltas with error feedback, mix the decoded updates
            and re-encode the snapshot rows — all one program."""
            if ring_mode == "fp32":                        # topk uplink
                ring_params, = ring_state
                base = jax.tree.map(
                    lambda x: jnp.take(x, base_slots, axis=0), ring_params)
            else:
                q, sc, fr = ring_state
                base = AG.ring_gather_lossy(q, sc, fr, ref, base_slots,
                                            fresh_read, is_fresh)
            trained, losses = jax.vmap(
                lambda bp, b: local_train(bp, b, ones_masks))(base, batches)
            delta = jax.tree.map(
                lambda t, b: t.astype(jnp.float32) - b.astype(jnp.float32),
                trained, base)
            sent, new_err, coords = jax.vmap(
                lambda d, e: CP.compress_update(d, e, comp, frac, bits))(
                    delta, err)
            hat = jax.tree.map(
                lambda b, s: (b.astype(jnp.float32) + s).astype(b.dtype),
                base, sent)
            w = jnp.full((bpad,), 1.0, jnp.float32) * mix_w
            if discount:
                w = w * AG.staleness_weights(stale, stale_a)
            w = w * valid
            coords_sum = jnp.sum(coords * valid)
            if ring_mode == "fp32":
                new_global, new_ring = AG.mix_bucket_ring(
                    global_params, ring_params, write_slots, hat, w)
                return (new_global, (new_ring,), losses, new_err,
                        coords_sum)
            new_global, q2, sc2, fr2 = AG.mix_bucket_ring_lossy(
                global_params, q, sc, fr, ref, write_slots, fresh_write,
                hat, w, bits)
            return new_global, (q2, sc2, fr2), losses, new_err, coords_sum

        return bucket_fn

    def _get_bucket_fn(self, bpad: int):
        """Bucket programs get their OWN cache, not the round-program LRU:
        pow2 padding bounds the key set at log2(max_bucket)+1, and sharing
        the LRU would let a sync round key evict bucket programs (and vice
        versa) into a silent recompile-per-revisit thrash."""
        if not hasattr(self, "_bucket_cache"):
            self._bucket_cache: Dict[int, object] = {}
        if bpad not in self._bucket_cache:
            # donate globals + ring: both are dead in the caller the moment
            # the call returns (immediately reassigned), and without
            # donation every bucket would copy the whole N+1-snapshot ring
            self._bucket_cache[bpad] = jax.jit(self._make_bucket_fn(bpad),
                                               donate_argnums=(0, 1))
        return self._bucket_cache[bpad]

    def bucket_programs(self) -> Dict[int, int]:
        """{padded bucket size: jit cache size} — the equivalence wall and
        the bench assert every value is 1 (no per-bucket retraces)."""
        return {bpad: fn._cache_size() for bpad, fn in
                getattr(self, "_bucket_cache", {}).items()}

    def run_async(self, capable_cycles: int, mix_weight: float = 0.5,
                  staleness_a: float = 0.5, eval_every: int = 1,
                  snapshot_cap: int = 64) -> List[dict]:
        if not self._scheme.async_native:
            # non-native schemes (soft-training mask evolution, control
            # variates, stale bases) need per-event state the bucket
            # program does not carry — only the sequential reference
            # implements that event-by-event; the bucket program trains
            # full models (the asyn/afo semantics)
            return super().run_async(capable_cycles, mix_weight,
                                     staleness_a, eval_every, snapshot_cap)
        clock = SimClock()
        self._reset_async_processes()
        n = len(self.clients)
        by_id = {c.cid: c for c in self.clients}
        ring = AG.SnapshotRing(self.global_params, snapshot_cap, n,
                               mode=self._ring_mode(), bits=self.comp_bits,
                               fresh_window=self.comp_fresh)
        lossy_ring = ring.mode != "fp32"
        for c in self.clients:
            c.staleness_anchor = 0
            ring.alloc.retain(0)
            clock.schedule(self._next_delay(c), c.cid)
        self.rec.set("agg_counter", 0)
        self.rec.set("events_processed", 0)
        self.rec.set("events_dropped", 0)
        self.bucket_sizes: List[int] = []
        done_fast = 0
        next_rec = eval_every if eval_every > 0 else 0
        while done_fast < capable_cycles and not clock.empty():
            evs = clock.pop_bucket(self.bucket_horizon, self.max_bucket)
            # dropout draws + capable-budget truncation, in event order —
            # the sequential loop stops mid-tie-group when the budget runs
            # out, so the bucket must cut at the same event and put the
            # unprocessed tail back on the heap untouched
            exec_evs: List[Event] = []
            drop_cids = set()
            budget = capable_cycles - done_fast
            cut = None
            for i, ev in enumerate(evs):
                if self.dropout is not None and self.dropout.drops(ev.cid):
                    drop_cids.add(ev.cid)
                    self.rec.event("drop", sim=ev.time, cid=ev.cid)
                    continue
                # the event stream mirrors the sequential reference: one
                # completion per executed event, emitted in pop order with
                # drops interleaved, staleness counted pre-mix
                self.rec.event("completion", sim=ev.time, cid=ev.cid,
                               stale=self.agg_counter + len(exec_evs)
                               - by_id[ev.cid].staleness_anchor)
                exec_evs.append(ev)
                if not by_id[ev.cid].is_straggler:
                    budget -= 1
                    if budget == 0:
                        cut = i + 1
                        break
            handled = evs if cut is None else evs[:cut]
            for ev in evs[len(handled):]:
                clock.schedule_at(ev.time, ev.cid)
            b = len(exec_evs)
            losses = stales = None
            if b:
                bpad = 1 << (b - 1).bit_length()
                # per-event batch draws in pop order — bit-identical rng
                # consumption to the sequential loop; padding replicates
                # slot 0 without touching the stream (PR 3's cohort seam)
                batches = self.adapter.sample_cohort(
                    self.rng, self.train_data,
                    [by_id[ev.cid].data_idx for ev in exec_evs],
                    self.local_steps, self.batch_size, pad_to=bpad)
                agg0 = self.agg_counter
                base_slots, write_slots, stales = [], [], []
                fresh_read, fresh_write, is_fresh = [], [], []
                F = ring.fresh_window
                for i, ev in enumerate(exec_evs):
                    c = by_id[ev.cid]
                    base_slots.append(ring.alloc.slot_of(c.staleness_anchor))
                    stales.append(agg0 + i - c.staleness_anchor)
                    # freshness is decided per EVENT (same stale < window
                    # rule as the sequential reference); the anchor's fp row
                    # is still live because agg ids inside the window can't
                    # have been overwritten (one fresh write per agg)
                    fresh_read.append(c.staleness_anchor % F)
                    is_fresh.append(1.0 if stales[-1] < F else 0.0)
                    new_agg = agg0 + i + 1
                    ring.alloc.release(c.staleness_anchor)
                    write_slots.append(ring.alloc.alloc(new_agg))
                    ring.alloc.retain(new_agg)
                    c.staleness_anchor = new_agg
                    fresh_write.append(new_agg % F)
                self.rec.set("agg_counter", agg0 + b)
                for s in stales:
                    self.rec.observe("staleness", s)
                CT.check_staleness(stales, a=staleness_a,
                                   tag="run_async[bucket]")
                pad = bpad - b
                _bt0 = time.perf_counter() if self.rec.armed else 0.0
                bucket_fn = self._get_bucket_fn(bpad)
                if self.compression == "none":
                    with CT.no_host_transfers("run_async[bucket]"):
                        self.global_params, ring.params, losses = bucket_fn(
                            self.global_params, ring.params,
                            jnp.asarray(base_slots + [0] * pad, jnp.int32),
                            jnp.asarray(write_slots + [ring.scratch] * pad,
                                        jnp.int32),
                            batches,
                            jnp.asarray(stales + [0] * pad, jnp.float32),
                            jnp.asarray([1.0] * b + [0.0] * pad,
                                        jnp.float32),
                            float(mix_weight), float(staleness_a))
                else:
                    cids = [ev.cid for ev in exec_evs]
                    err = self._err_store.gather(cids + [cids[0]] * pad)
                    ring_state = ((ring.q, ring.scales, ring.fresh_buf)
                                  if lossy_ring else (ring.params,))
                    ref = ring.ref if lossy_ring else None
                    with CT.no_host_transfers("run_async[bucket]"):
                        (self.global_params, ring_state, losses, new_err,
                         coords) = bucket_fn(
                            self.global_params, ring_state, ref, err,
                            jnp.asarray(base_slots + [0] * pad, jnp.int32),
                            jnp.asarray(write_slots + [ring.scratch] * pad,
                                        jnp.int32),
                            jnp.asarray(fresh_read + [0] * pad, jnp.int32),
                            # padding writes the fresh buffer's scratch row
                            jnp.asarray(fresh_write + [F] * pad, jnp.int32),
                            jnp.asarray(is_fresh + [1.0] * pad,
                                        jnp.float32),
                            batches,
                            jnp.asarray(stales + [0] * pad, jnp.float32),
                            jnp.asarray([1.0] * b + [0.0] * pad,
                                        jnp.float32),
                            float(mix_weight), float(staleness_a))
                        self.rec.accum("uplink_coords", coords)
                    if lossy_ring:
                        ring.q, ring.scales, ring.fresh_buf = ring_state
                    else:
                        ring.params, = ring_state
                    self._err_store.scatter(
                        cids, jax.tree.map(lambda x: x[:b], new_err))
                self.rec.inc("uplink_updates", b)
                self.rec.inc("events_processed", b)
                self.rec.inc("downlink_updates", b)  # per-event ring pulls
                self.bucket_sizes.append(b)
                self.rec.observe("bucket_size", b)
                self.rec.observe("queue_depth", len(clock))
                self.rec.event(
                    "bucket", sim=clock.now, size=b, pad=bpad - b,
                    queue=len(clock),
                    wall_ms=(time.perf_counter() - _bt0) * 1e3)
                done_fast += sum(1 for ev in exec_evs
                                 if not by_id[ev.cid].is_straggler)
            # reschedule every handled event in event order (arrival-stream
            # parity with the sequential reference; each process owns its
            # rng, so drop draws above never perturb these)
            for ev in handled:
                delay = self._next_delay(by_id[ev.cid])
                if ev.cid in drop_cids:
                    delay *= self.dropout.penalty
                clock.schedule_at(ev.time + delay, ev.cid)
            self.rec.inc("events_dropped", len(drop_cids))
            if next_rec and b and done_fast >= next_rec:
                self.history.append({
                    "scheme": self.scheme, "cycle": done_fast,
                    "time": clock.now,
                    "record_cadence": "bucket",
                    self.adapter.metric_name: self.evaluate(),
                    # behind the eval gate: evaluate() just synced
                    "loss": float(np.mean(np.asarray(losses)[:b])),  # repro: noqa[R3]
                    "staleness": float(np.mean(stales)),
                    "bucket": b,
                    "downlink_mb": self.downlink_bytes() / 1e6})
                row = self.history[-1]
                self.rec.event("history", sim=row["time"],
                               **{k: v for k, v in row.items()
                                  if k != "time"})
                next_rec = (done_fast // eval_every + 1) * eval_every
        self.rec.set("snapshot_peak", ring.alloc.peak_live)
        self.rec.set("snapshot_anchor_misses", ring.alloc.anchor_misses)
        self.rec.set("queue_peak", clock.peak_depth)
        if CT.enabled():
            CT.check_ring(ring, len(self.clients),
                          tag="run_async[bucket].ring")
            CT.check_compile_budget(self, tag="run_async[bucket].compile")
        self._obs_finish("run_async[bucket]")
        return self.history


class BatchedFLRun(AsyncFLRun):
    """Batched sync engine: one jitted vmapped program per round.

    Per-client Helios state (masks, scores, skip_counts, volume, rng,
    cycle) is stacked along a leading client axis.  Clients are split into
    two COHORTS so every control decision inside the traced program is
    uniform:

      * soft-training stragglers — begin_cycle (batched PRNG split + Eq. 2
        selection) -> masked local training (lax.scan over steps) ->
        cycle_scores / end_cycle, all under one vmap;
      * capable clients — full-model local training under a second vmap.

    Both cohorts and the Eq. 10 / masked-mean aggregation trace into a
    SINGLE compiled round program, so host-loop dispatch overhead is O(1)
    per round instead of O(clients).  Host-side pieces run through the
    shared template-method protocol in the same order as the sequential
    reference — which keeps the engines trajectory-equivalent for a fixed
    seed (up to batched-reduction float error).

    The async schemes run on the inherited bucketed event engine
    (:class:`AsyncFLRun`) — no sequential fallback.
    """

    def __post_init__(self):
        super().__post_init__()
        self._build_batched()

    # ------------------------------------------------------------------
    def _get_round_fn(self, n_s: int, n_c: int):
        # the warmup phase is part of the program identity: warmup rounds
        # run the EXACT program a compression="none" run compiles (so the
        # prefix is bit-identical), steady rounds the codec program — at
        # most one extra cache entry, each still holding one program
        on = self._comp_active()
        return self._get_cached_program(
            (n_s, n_c, on), lambda: jax.jit(self._make_round_fn(n_s, n_c,
                                                                on)))

    def _build_batched(self):
        soft = self._scheme.soft_training
        self._s_idx = [i for i, c in enumerate(self.clients)
                       if soft and c.is_straggler]
        self._c_idx = [i for i, c in enumerate(self.clients)
                       if not (soft and c.is_straggler)]
        if self.participation:
            # sampled cohorts change membership per round: per-client
            # ``helios_state`` stays authoritative and each round stacks /
            # unstacks just its cohort (_train_cohort_sampled) — no
            # persistent whole-fleet stacked state to fall out of sync
            self._sstate = None
            return
        # stacked[unperm] restores original client order for aggregation
        self._unperm = jnp.asarray(
            np.argsort(np.asarray(self._s_idx + self._c_idx)), jnp.int32)
        self._sstate = ST.stack_states(
            [self.clients[i].helios_state for i in self._s_idx]) \
            if self._s_idx else None
        # unperm is a traced arg, so programs depend only on (n_s, n_c)
        self._round_fn = self._get_round_fn(len(self._s_idx),
                                            len(self._c_idx))

    def _make_round_fn(self, n_s: int, n_c: int, comp_on: bool = True):
        adapter, opt = self.adapter, self.opt
        scheme, hcfg = self._scheme, self.hcfg
        hcfg_eff = scheme.effective_hcfg(hcfg)
        agg_mode = scheme.agg_mode(hcfg)
        ones_masks = ST.full_masks(adapter.schema)
        local_train = _make_local_train(adapter, opt, scheme.uses_control)
        comp = self.compression if comp_on else "none"
        frac, bits = self.comp_frac, self.comp_bits
        inv = 1.0 / (self.local_steps * self.lr)

        def round_fn(global_params, sstate, s_batch, c_batch, unperm,
                     *extras):
            # scheme extras ride positionally, in flag order (the host
            # _round_extras builds the mirror-image tuple)
            extras = list(extras)
            if scheme.uses_control:
                c_global, c_rows = extras.pop(0), extras.pop(0)
            if scheme.uses_stale_base:
                stale_base = extras.pop(0)
                stale_flags, discs = extras.pop(0), extras.pop(0)
            err = extras.pop(0) if comp != "none" else None

            def cat(parts):
                if len(parts) == 1:
                    return jax.tree.map(
                        lambda x: jnp.take(x, unperm, axis=0), parts[0])
                return jax.tree.map(
                    lambda *xs: jnp.take(jnp.concatenate(xs), unperm,
                                         axis=0), *parts)

            parts_p, parts_r, parts_l, parts_m = [], [], [], []
            new_sstate = sstate
            if n_s:
                def one_straggler(st, batches):
                    st = ST.begin_cycle(st, hcfg_eff)
                    masks = st["masks"]
                    p, loss = local_train(global_params, batches, masks)
                    if scheme.use_delta_scores:
                        scores = adapter.cycle_scores(p, global_params)
                    else:                                  # random [12]
                        scores = st["scores"]
                    st = ST.end_cycle(st, scores, hcfg_eff)
                    return (p, st, MK.selected_fraction(masks), loss, masks)

                p, new_sstate, r, l, m = jax.vmap(one_straggler)(
                    sstate, s_batch)
                parts_p.append(p), parts_r.append(r), parts_l.append(l)
                parts_m.append(m)
            if n_c:
                if scheme.uses_control:
                    corr = jax.tree.map(lambda cg, cr: cg - cr,
                                        c_global, c_rows)

                    def one_capable(batches, co):
                        return local_train(global_params, batches,
                                           ones_masks, co)

                    p, l = jax.vmap(one_capable)(c_batch, corr)
                elif scheme.uses_stale_base:
                    def one_capable(batches, flag, disc):
                        base = jax.tree.map(
                            lambda s, g: jnp.where(flag > 0,
                                                   s.astype(g.dtype), g),
                            stale_base, global_params)
                        p, loss = local_train(base, batches, ones_masks)
                        # virtualize onto the current global (capable rows:
                        # base == global, disc == 1 => exactly p)
                        p = jax.tree.map(
                            lambda g, y, b: (g.astype(jnp.float32) + disc
                                             * (y.astype(jnp.float32)
                                                - b.astype(jnp.float32))
                                             ).astype(g.dtype),
                            global_params, p, base)
                        return p, loss

                    p, l = jax.vmap(one_capable)(c_batch, stale_flags,
                                                 discs)
                else:
                    def one_capable(batches):
                        return local_train(global_params, batches,
                                           ones_masks)

                    p, l = jax.vmap(one_capable)(c_batch)
                parts_p.append(p)
                parts_r.append(jnp.ones((n_c,), jnp.float32))
                parts_l.append(l)
                parts_m.append(jax.tree.map(
                    lambda v: jnp.ones((n_c,) + v.shape, jnp.float32),
                    ones_masks))
            stacked = cat(parts_p)
            ratios = cat(parts_r)
            losses = cat(parts_l)
            ctrl_out = ()
            if scheme.uses_control:
                # option-II control update from the RAW trained rows,
                # before any codec touches them
                dc = jax.tree.map(
                    lambda g, t, cg: (g.astype(jnp.float32)
                                      - t.astype(jnp.float32)) * inv - cg,
                    global_params, stacked, c_global)
                new_c_rows = jax.tree.map(lambda rr, d: rr + d, c_rows, dc)
                dc_sum = jax.tree.map(lambda d: jnp.sum(d, axis=0), dc)
                ctrl_out = (new_c_rows, dc_sum)
            if comp == "none":
                pmasks = adapter.expand_masks_batch(cat(parts_m),
                                                    global_params) \
                    if agg_mode == "masked_mean" else None
                new_global = AG.aggregate_stacked(agg_mode, global_params,
                                                  stacked, ratios, pmasks)
                return (new_global, new_sstate, ratios, losses) + ctrl_out
            # compressed uplink: every stacked update goes through the
            # codec + error feedback, masked so Eq. 2-frozen coordinates
            # are never encoded (capable rows carry ones masks)
            pm = adapter.expand_masks_batch(cat(parts_m), global_params)
            delta = jax.tree.map(
                lambda t, g: t.astype(jnp.float32) - g.astype(jnp.float32),
                stacked, global_params)
            sent, new_err, coords = jax.vmap(
                lambda d, e, m: CP.compress_update(d, e, comp, frac, bits,
                                                   m))(delta, err, pm)
            stacked = jax.tree.map(
                lambda g, s: (g.astype(jnp.float32) + s).astype(g.dtype),
                global_params, sent)
            pmasks = pm if agg_mode == "masked_mean" else None
            new_global = AG.aggregate_stacked(agg_mode, global_params,
                                              stacked, ratios, pmasks)
            return (new_global, new_sstate, ratios, losses, new_err,
                    jnp.sum(coords)) + ctrl_out

        return round_fn

    # ------------------------------------------------------------------
    def _sample_cohort_batches(self):
        # consume self.rng in CLIENT order — bit-identical draws to the
        # sequential engine's per-client loop
        per = [self._sample_batches(c) for c in self.clients]

        def stack(idx):
            if not idx:
                return None
            return jax.tree.map(lambda *xs: jnp.stack(xs),
                                *[per[i] for i in idx])

        return stack(self._s_idx), stack(self._c_idx)

    # -- template hooks -------------------------------------------------
    def _round_extras(self, row_clients: List[Client]):
        """Scheme-specific traced inputs, in the order the round program
        pops them (mirrors _make_round_fn).  Rows follow the program's
        stacked row order — the full-model schemes that use extras have no
        soft cohort, so that is exactly ``row_clients`` order."""
        extras = ()
        if self._scheme.uses_control:
            extras += (self._c_global, self._ctrl_store.gather(
                [c.cid for c in row_clients]))
        if self._scheme.uses_stale_base:
            flags = jnp.asarray([1.0 if c.is_straggler else 0.0
                                 for c in row_clients], jnp.float32)
            discs = jnp.asarray([self._stale_disc if c.is_straggler else 1.0
                                 for c in row_clients], jnp.float32)
            extras += (self._stale_base, flags, discs)
        return extras

    def _apply_round_outs(self, row_clients: List[Client], outs) -> None:
        """Write back the round program's trailing scheme outputs
        (SCAFFOLD: per-client control rows + the server control fold)."""
        if self._scheme.uses_control:
            new_c_rows, dc_sum = outs
            self._ctrl_store.scatter([c.cid for c in row_clients],
                                     new_c_rows)
            n = float(len(self.clients))
            self._c_global = jax.tree.map(lambda c, d: c + d / n,
                                          self._c_global, dc_sum)

    def _train_cohort(self, cohort: List[int], cclients: List[Client]):
        if self.participation:
            return self._train_cohort_sampled(cohort, cclients)
        s_batch, c_batch = self._sample_cohort_batches()
        round_fn = self._get_round_fn(len(self._s_idx), len(self._c_idx))
        extras = self._round_extras(self.clients)
        if not self._comp_active():
            outs = round_fn(self.global_params, self._sstate,
                            s_batch, c_batch, self._unperm, *extras)
            self.global_params, self._sstate, ratios, losses = outs[:4]
            self._apply_round_outs(self.clients, outs[4:])
            return losses, ratios
        # stacked rows are in original client order (cat() un-permutes),
        # so the error rows gather/scatter in that same order
        cids = [c.cid for c in self.clients]
        err = self._err_store.gather(cids)
        outs = round_fn(self.global_params, self._sstate,
                        s_batch, c_batch, self._unperm, *extras, err)
        (self.global_params, self._sstate, ratios, losses, new_err,
         coords) = outs[:6]
        self.rec.accum("uplink_coords", coords)
        self._err_store.scatter(cids, new_err)
        self._apply_round_outs(self.clients, outs[6:])
        # device arrays on purpose — _record_round converts behind the gate
        return losses, ratios

    def _train_cohort_sampled(self, cohort: List[int],
                              cclients: List[Client]):
        """Partial participation: stack just the drawn cohort.

        Per-client ``helios_state`` is the source of truth between rounds
        (unsampled clients' state is literally untouched); the cohort's
        straggler rows are stacked, run through the (n_s, n_c)-shaped round
        program from the LRU cache, and unstacked back.  Batch draws
        consume ``self.rng`` in cohort order — the same order as the
        sequential engine's loop — so trajectories stay replay-equivalent.
        """
        soft = self._scheme.soft_training
        s_pos = [j for j, c in enumerate(cclients)
                 if soft and c.is_straggler]
        c_pos = [j for j, c in enumerate(cclients)
                 if not (soft and c.is_straggler)]
        unperm = jnp.asarray(np.argsort(np.asarray(s_pos + c_pos)),
                             jnp.int32)
        per = [self._sample_batches(c) for c in cclients]

        def stack(pos):
            if not pos:
                return None
            return jax.tree.map(lambda *xs: jnp.stack(xs),
                                *[per[j] for j in pos])

        sstate = ST.stack_states([cclients[j].helios_state
                                  for j in s_pos]) if s_pos else None
        round_fn = self._get_round_fn(len(s_pos), len(c_pos))
        extras = self._round_extras(cclients)
        if not self._comp_active():
            outs = round_fn(self.global_params, sstate, stack(s_pos),
                            stack(c_pos), unperm, *extras)
            self.global_params, sstate, ratios, losses = outs[:4]
            self._apply_round_outs(cclients, outs[4:])
        else:
            cids = [c.cid for c in cclients]
            err = self._err_store.gather(cids)
            outs = round_fn(self.global_params, sstate, stack(s_pos),
                            stack(c_pos), unperm, *extras, err)
            (self.global_params, sstate, ratios, losses, new_err,
             coords) = outs[:6]
            self.rec.accum("uplink_coords", coords)
            self._err_store.scatter(cids, new_err)
            self._apply_round_outs(cclients, outs[6:])
        if s_pos:
            for j, st in zip(s_pos, ST.unstack_states(sstate, len(s_pos))):
                cclients[j].helios_state = st
        # device arrays on purpose — _record_round converts behind the gate
        return losses, ratios

    def _write_volumes(self, cohort: List[int], cclients: List[Client],
                       upd: List[int]) -> None:
        if self.participation:
            super()._write_volumes(cohort, cclients, upd)
        elif self._s_idx:
            self._sstate = ST.set_volumes(
                self._sstate, [self.clients[i].volume for i in self._s_idx])

    def _finish_sync(self) -> None:
        # keep per-client helios_state fresh so callers that snapshot
        # clients (checkpointing, inspection) never see round-0 state
        if not self.participation:
            self.sync_client_states()

    # ------------------------------------------------------------------
    def run_async(self, *args, **kwargs) -> List[dict]:
        if self._scheme.async_native:
            return super().run_async(*args, **kwargs)      # bucketed engine
        # non-native schemes delegate to the sequential event loop (via
        # the AsyncFLRun guard), which mutates per-client helios_state:
        # materialize it from the stacked/population state, run, restack
        self.sync_client_states()
        hist = super().run_async(*args, **kwargs)
        self._build_batched()
        return hist

    def sync_client_states(self) -> None:
        """Write the stacked cohort state back into per-client
        ``helios_state`` (for checkpointing / inspection / elastic ops)."""
        if self._s_idx and self._sstate is not None:
            for i, st in zip(self._s_idx,
                             ST.unstack_states(self._sstate,
                                               len(self._s_idx))):
                self.clients[i].helios_state = st

    def add_client(self, profile: DeviceProfile, data_idx: np.ndarray,
                   white_box: bool = True) -> Client:
        self.sync_client_states()
        c = super().add_client(profile, data_idx, white_box)
        self._build_batched()                 # cohort shapes changed: re-jit
        return c

    def remove_client(self, cid: int) -> None:
        self.sync_client_states()
        super().remove_client(cid)
        self._build_batched()


@dataclasses.dataclass
class ShardedFLRun(BatchedFLRun):
    """Client-sharded round engine: the batched program, shard_mapped over a
    1-D ``("clients",)`` device mesh (launch/mesh.make_client_mesh).

    Population scale comes from three ingredients on top of
    :class:`BatchedFLRun`:

    * **Persistent population state** — every client's Helios state lives as
      one row of a stacked pytree (``core.soft_train.init_population``, built
      without materializing N per-client dicts).  Each round gathers the
      sampled cohort's rows, runs them, and scatters them back; unsampled
      rows are bit-untouched.
    * **One shape-stable round program** — the cohort is padded to
      ``ceil(K / devices) * devices`` slots (padding replicates the first
      client's batch, gets zero aggregation weight, and never consumes host
      RNG), and soft-training vs. capable clients are selected by a traced
      per-slot flag instead of cohort splitting.  One compiled program
      serves every draw: no recompiles across sampled cohorts.
    * **Client-parallel execution** — inside shard_map each device vmaps
      over its block of cohort rows; Eq. 10 / masked-mean aggregation is a
      local weighted partial sum followed by a single cross-device psum over
      the ``clients`` axis.

    Same seed => same trajectory as FLRun/BatchedFLRun up to float
    reduction-order error (the equivalence wall in
    tests/test_sharded_engine.py pins all three engines together).
    """

    #: optional explicit device mesh with a ``clients`` axis; by default a
    #: 1-D mesh over (at most cohort-size) visible devices is built lazily
    mesh: Optional[Mesh] = None

    # ------------------------------------------------------------------
    def _init_helios(self):
        # per-client dicts stay unmaterialized: the population state is
        # built stacked in _build_batched (sync_client_states writes rows
        # back on demand for checkpointing / elastic churn / inspection)
        pass

    def _build_batched(self):
        # _draw_cohort never returns more than the population, so clamp the
        # slot count too — otherwise participation > N pads every round
        # with zero-weight training slots
        k = min(self.participation, len(self.clients)) or len(self.clients)
        self._mesh = self.mesh if self.mesh is not None \
            else make_client_mesh(k)
        d = self._mesh.devices.size
        self._kpad = -(-k // d) * d
        # place the globals mesh-replicated up front: round 1 then sees the
        # same input sharding the round program outputs, so the compile
        # cache holds exactly ONE program from the first call on
        self.global_params = jax.device_put(
            self.global_params,
            jax.sharding.NamedSharding(self._mesh, P()))
        # the population state lives HOST-SIDE (numpy leaves): rounds gather
        # K rows to device and scatter them back in place, so N never
        # round-trips and the jit input signature is draw-invariant
        if all(c.helios_state is None for c in self.clients):
            self._pop_state = ST.host_states(ST.init_population(
                self.adapter.schema, [c.volume for c in self.clients],
                [c.cid for c in self.clients]))
        else:
            # elastic path: sync_client_states materialized fresh dicts
            # before the client list changed — restack them
            self._pop_state = ST.host_states(ST.stack_states(
                [c.helios_state for c in self.clients]))
        # warm the cache; the attribute stays for monitoring
        # (benchmarks read run._round_fn._cache_size())
        self._round_fn = self._get_sharded_fn()

    def _get_sharded_fn(self):
        # same warmup-phase cache split as _get_round_fn: one program per
        # (kpad, codec-on/off) signature
        on = self._comp_active()
        return self._get_cached_program(
            ("sharded", self._kpad, on),
            lambda: self._make_sharded_round_fn(self._kpad, on))

    def sync_client_states(self) -> None:
        """Materialize per-client ``helios_state`` views from the population
        rows (checkpointing / inspection / elastic ops)."""
        for i, c in enumerate(self.clients):
            c.helios_state = self.client_state(i)

    def client_state(self, i: int) -> dict:
        """Row ``i`` (client-list position) of the population state, as an
        immutable device snapshot (host rows are mutated in place)."""
        return jax.tree.map(lambda x: jnp.asarray(x[i]), self._pop_state)

    # ------------------------------------------------------------------
    def _make_sharded_round_fn(self, kpad: int, comp_on: bool = True):
        adapter, opt = self.adapter, self.opt
        scheme, hcfg = self._scheme, self.hcfg
        hcfg_eff = scheme.effective_hcfg(hcfg)
        agg_mode = scheme.agg_mode(hcfg)
        ones_masks = ST.full_masks(adapter.schema)
        local_train = _make_local_train(adapter, opt, scheme.uses_control)
        comp = self.compression if comp_on else "none"
        frac, bits = self.comp_frac, self.comp_bits
        inv = 1.0 / (self.local_steps * self.lr)

        def round_body(global_params, cstate, batches, is_soft, valid,
                       *extras):
            extras = list(extras)
            if scheme.uses_control:
                c_global, c_rows = extras.pop(0), extras.pop(0)
                corr = jax.tree.map(lambda cg, cr: cg - cr, c_global,
                                    c_rows)
            if scheme.uses_stale_base:
                stale_base = extras.pop(0)
                stale_flags, discs = extras.pop(0), extras.pop(0)
            err = extras.pop(0) if comp != "none" else None

            # block-local views: leading axis = kpad / n_devices rows
            def one_client(st, b, soft_flag, *row):
                st_b = ST.begin_cycle(st, hcfg_eff)
                masks = jax.tree.map(
                    lambda m, o: jnp.where(soft_flag > 0, m, o),
                    st_b["masks"], ones_masks)
                if scheme.uses_control:
                    co, = row
                    p, loss = local_train(global_params, b, masks, co)
                elif scheme.uses_stale_base:
                    flag, disc = row
                    base = jax.tree.map(
                        lambda s, g: jnp.where(flag > 0, s.astype(g.dtype),
                                               g),
                        stale_base, global_params)
                    p, loss = local_train(base, b, masks)
                    p = jax.tree.map(
                        lambda g, y, bb: (g.astype(jnp.float32) + disc
                                          * (y.astype(jnp.float32)
                                             - bb.astype(jnp.float32))
                                          ).astype(g.dtype),
                        global_params, p, base)
                else:
                    p, loss = local_train(global_params, b, masks)
                if scheme.use_delta_scores:
                    scores = adapter.cycle_scores(p, global_params)
                else:                                      # random [12] / syn
                    scores = st_b["scores"]
                st_e = ST.end_cycle(st_b, scores, hcfg_eff)
                # capable (and padding) slots keep their state bit-identical:
                # the discarded begin/end cycle never leaks back
                new_st = jax.tree.map(
                    lambda a, o: jnp.where(soft_flag > 0, a, o), st_e, st)
                ratio = jnp.where(soft_flag > 0,
                                  MK.selected_fraction(st_b["masks"]), 1.0)
                return p, new_st, ratio, loss, masks

            row_extra = ()
            if scheme.uses_control:
                row_extra = (corr,)
            elif scheme.uses_stale_base:
                row_extra = (stale_flags, discs)
            p, new_state, ratios, losses, masks = jax.vmap(one_client)(
                cstate, batches, is_soft, *row_extra)
            ctrl_out = ()
            if scheme.uses_control:
                # option-II control update from the RAW trained rows;
                # padding rows are masked out of the server fold by valid
                dc = jax.tree.map(
                    lambda g, t, cg: (g.astype(jnp.float32)
                                      - t.astype(jnp.float32)) * inv - cg,
                    global_params, p, c_global)
                new_c_rows = jax.tree.map(lambda rr, d: rr + d, c_rows, dc)
                dc_sum = jax.tree.map(
                    lambda d: jax.lax.psum(
                        jnp.sum(d * valid.reshape((-1,) + (1,)
                                                  * (d.ndim - 1)), axis=0),
                        "clients"), dc)
                ctrl_out = (new_c_rows, dc_sum)
            pm = adapter.expand_masks_batch(masks, global_params) \
                if (comp != "none" or agg_mode == "masked_mean") else None
            if comp != "none":
                # codec runs shard-local on each device's cohort rows;
                # only the coordinate count crosses devices (one psum)
                delta = jax.tree.map(
                    lambda t, g: t.astype(jnp.float32)
                    - g.astype(jnp.float32), p, global_params)
                sent, new_err, coords = jax.vmap(
                    lambda d, e, m: CP.compress_update(d, e, comp, frac,
                                                       bits, m))(
                        delta, err, pm)
                p = jax.tree.map(
                    lambda g, s: (g.astype(jnp.float32) + s).astype(g.dtype),
                    global_params, sent)
                coords = jax.lax.psum(jnp.sum(coords * valid), "clients")
            base = ratios if agg_mode != "uniform" else jnp.ones_like(ratios)
            w = base * valid
            a = w / jnp.maximum(jax.lax.psum(jnp.sum(w), "clients"), 1e-9)
            if agg_mode == "masked_mean":
                pmasks = pm
                num = jax.tree.map(
                    lambda m, t: jnp.sum(
                        a.reshape((-1,) + (1,) * (t.ndim - 1)) * m
                        * t.astype(jnp.float32), axis=0), pmasks, p)
                den = jax.tree.map(
                    lambda m: jnp.sum(
                        a.reshape((-1,) + (1,) * (m.ndim - 1)) * m, axis=0),
                    pmasks)
                num, den = jax.lax.psum((num, den), "clients")
                new_g = jax.tree.map(
                    lambda g, nu, de: jnp.where(
                        de > 0, nu / jnp.maximum(de, 1e-9),
                        g.astype(jnp.float32)).astype(g.dtype),
                    global_params, num, den)
            else:
                part = jax.tree.map(
                    lambda t: jnp.tensordot(a, t.astype(jnp.float32),
                                            axes=1), p)
                part = jax.lax.psum(part, "clients")
                new_g = jax.tree.map(lambda g, t: t.astype(g.dtype),
                                     global_params, part)
            if comp != "none":
                return (new_g, new_state, ratios, losses, new_err,
                        coords) + ctrl_out
            return (new_g, new_state, ratios, losses) + ctrl_out

        # check_rep=False: remat checkpoint_name (transformer stacks) has no
        # replication rule on current JAX; the psum above still leaves
        # new_g replicated in practice
        in_specs = (P(), P("clients"), P("clients"), P("clients"),
                    P("clients"))
        out_specs = (P(), P("clients"), P("clients"), P("clients"))
        if scheme.uses_control:
            in_specs += (P(), P("clients"))                # c_global, rows
        if scheme.uses_stale_base:
            in_specs += (P(), P("clients"), P("clients"))  # base/flags/disc
        if comp != "none":
            in_specs += (P("clients"),)                    # err rows
            out_specs += (P("clients"), P())               # new_err, coords
        if scheme.uses_control:
            out_specs += (P("clients"), P())               # new rows, dc_sum
        sharded = shard_map(
            round_body, mesh=self._mesh,
            in_specs=in_specs, out_specs=out_specs, check_rep=False)
        return jax.jit(sharded)

    # -- template hooks -------------------------------------------------
    def _round_extras(self, row_clients: List[Client]):
        """Sharded extras are PADDED to the program's kpad slots: padding
        replicates the first client's control row (its dc contribution is
        masked out by ``valid`` in-program) and trains from the fresh
        global at discount 1.  Dense trees are pinned mesh-replicated
        every round (idempotent device_put, same reason as the globals in
        _build_batched): after round 1 they are built FROM mesh-sharded
        round outputs, and letting the input sharding drift would retrace
        the round program against its compile budget."""
        rep = jax.sharding.NamedSharding(self._mesh, P())
        pad = self._kpad - len(row_clients)
        extras = ()
        if self._scheme.uses_control:
            cids = [c.cid for c in row_clients]
            extras += (jax.device_put(self._c_global, rep),
                       self._ctrl_store.gather(cids + [cids[0]] * pad))
        if self._scheme.uses_stale_base:
            flags = jnp.asarray(
                [1.0 if c.is_straggler else 0.0 for c in row_clients]
                + [0.0] * pad, jnp.float32)
            discs = jnp.asarray(
                [self._stale_disc if c.is_straggler else 1.0
                 for c in row_clients] + [1.0] * pad, jnp.float32)
            extras += (jax.device_put(self._stale_base, rep), flags, discs)
        return extras

    def _apply_round_outs(self, row_clients: List[Client], outs) -> None:
        if self._scheme.uses_control:
            new_c_rows, dc_sum = outs
            k = len(row_clients)
            self._ctrl_store.scatter(
                [c.cid for c in row_clients],
                jax.tree.map(lambda x: x[:k], new_c_rows))
            n = float(len(self.clients))
            self._c_global = jax.tree.map(lambda c, d: c + d / n,
                                          self._c_global, dc_sum)

    def _train_cohort(self, cohort: List[int], cclients: List[Client]):
        soft = self._scheme.soft_training
        k, kpad = len(cohort), self._kpad
        idx = np.asarray(cohort + [cohort[0]] * (kpad - k))
        is_soft = jnp.asarray(
            [1.0 if (soft and c.is_straggler) else 0.0
             for c in cclients] + [0.0] * (kpad - k), jnp.float32)
        valid = jnp.asarray([1.0] * k + [0.0] * (kpad - k), jnp.float32)
        batches = self.adapter.sample_cohort(
            self.rng, self.train_data, [c.data_idx for c in cclients],
            self.local_steps, self.batch_size, pad_to=kpad)
        cstate = ST.gather_states_host(self._pop_state, idx)
        round_fn = self._get_sharded_fn()
        extras = self._round_extras(cclients)
        if not self._comp_active():
            outs = round_fn(self.global_params, cstate, batches, is_soft,
                            valid, *extras)
            self.global_params, new_cstate, ratios, losses = outs[:4]
            self._apply_round_outs(cclients, outs[4:])
        else:
            err = self._err_store.gather(
                [self.clients[i].cid for i in idx])
            outs = round_fn(self.global_params, cstate, batches, is_soft,
                            valid, *extras, err)
            (self.global_params, new_cstate, ratios, losses, new_err,
             coords) = outs[:6]
            self.rec.accum("uplink_coords", coords)
            self._err_store.scatter(
                [self.clients[i].cid for i in cohort],
                jax.tree.map(lambda x: x[:k], new_err))
            self._apply_round_outs(cclients, outs[6:])
        ST.scatter_states_host(
            self._pop_state, cohort,
            jax.tree.map(lambda x: x[:k], new_cstate))
        # device slices on purpose — _record_round converts behind the gate
        return losses[:k], ratios[:k]

    def _write_volumes(self, cohort: List[int], cclients: List[Client],
                       upd: List[int]) -> None:
        self._pop_state["volume"][np.asarray([cohort[j] for j in upd])] = \
            np.asarray([cclients[j].volume for j in upd], np.float32)

    def _finish_sync(self) -> None:
        pass                # population rows ARE the authoritative state

    def _contract_state_masks(self):
        # straggler rows of the host-resident population state, checked
        # stacked (check_mask_invariants accepts leading client axes)
        s_idx = [i for i, c in enumerate(self.clients) if c.is_straggler]
        pop = getattr(self, "_pop_state", None)
        if not s_idx or not isinstance(pop, dict) or "masks" not in pop:
            return []
        idx = np.asarray(s_idx)
        return [{k: v[idx] for k, v in pop["masks"].items()}]


def setup_clients(profiles: Sequence[DeviceProfile],
                  parts: Sequence[np.ndarray],
                  hcfg: HeliosConfig,
                  identification: str = "resource") -> List[Client]:
    """Straggler identification (§IV.B) + volume targets (§IV.C)."""
    n = len(profiles)
    sim_times = [cycle_time(p, 1.0) for p in profiles]
    if identification == "resource":
        _, stragglers = identify_resource_based(
            workload_gflop=100.0, memory_mb=200.0, devices=list(profiles))
    else:
        _, stragglers = identify_time_based(lambda d: None, n,
                                            simulated_times=sim_times)
    pace = _median_pace([t for i, t in enumerate(sim_times)
                         if i not in stragglers])
    clients = []
    for i, p in enumerate(profiles):
        is_s = i in stragglers
        vol = VOL.volume_from_profile(sim_times[i], pace, hcfg.min_volume) \
            if is_s else 1.0
        clients.append(Client(cid=i, profile=p, data_idx=parts[i],
                              volume=vol, is_straggler=is_s))
    return clients
