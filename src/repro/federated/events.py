"""Discrete-event core for the async engines (asyn / afo).

Real heterogeneous fleets are event-driven: clients pull the current global
model, train at their own pace, and their updates arrive whenever they
arrive.  This module is the simulator's backbone for that regime:

* :class:`SimClock` — a deterministic virtual clock.  The event heap is
  keyed ``(time, cid)``, so **equal-time completions always pop in client-id
  order** on every engine.  That determinism is what makes fixed-seed async
  trajectories engine-comparable: the sequential reference (FLRun.run_async)
  and the bucketed engine (AsyncFLRun) consume the identical event order.
* :meth:`SimClock.pop_bucket` — pops a *bucket* of near-simultaneous
  completion events (all events within ``horizon`` of the earliest pending
  one).  With ``horizon=0.0`` a bucket is exactly one tie-group; because a
  client's next completion is strictly later than its current one
  (cycle times are positive), tie-group bucketing cannot reorder events
  relative to the one-at-a-time loop — the bucketed engine stays
  trajectory-equivalent to the sequential reference.  ``horizon > 0``
  trades that exactness for bigger buckets (the clock then advances at
  bucket granularity).
* Pluggable **arrival** and **dropout** processes.  Each process owns its
  own host RNG stream (re-seeded from the run seed at every ``run_async``
  call), and both engines invoke them once per event *in pop order* — so a
  jittered or lossy fleet still replays identically across engines.

Only client ids live in the heap; what a completion *means* (train, mix,
snapshot) is the engine's business (federated.runtime).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List

import numpy as np


@dataclasses.dataclass(frozen=True, order=True)
class Event:
    """One client-completion event: orderable by (time, cid)."""

    time: float
    cid: int


class SimClock:
    """Deterministic event-driven virtual clock.

    The heap is keyed ``(time, cid)``: ties pop in client-id order by
    construction rather than by incidental insertion order.  ``now`` is
    monotone — re-inserting an already-popped event (bucket truncation)
    never rewinds it.
    """

    def __init__(self):
        self.now = 0.0
        #: high-water queue depth (telemetry: repro.obs reads it at the
        #: end of a run, the per-pop depth is observed engine-side)
        self.peak_depth = 0
        self._q: list = []

    def schedule(self, delay: float, cid: int) -> None:
        heapq.heappush(self._q, (self.now + delay, cid))
        self.peak_depth = max(self.peak_depth, len(self._q))

    def schedule_at(self, time: float, cid: int) -> None:
        """Absolute-time (re)insertion — bucket truncation puts unprocessed
        events back exactly where they were."""
        heapq.heappush(self._q, (time, cid))
        self.peak_depth = max(self.peak_depth, len(self._q))

    def pop(self) -> int:
        t, cid = heapq.heappop(self._q)
        self.now = max(self.now, t)
        return cid

    def pop_bucket(self, horizon: float = 0.0,
                   max_size: int = 0) -> List[Event]:
        """Pop every event within ``horizon`` of the earliest pending one
        (at most ``max_size`` when positive), in (time, cid) order.

        Each client has at most one outstanding completion, so a bucket
        never contains the same cid twice.
        """
        evs: List[Event] = []
        if not self._q:
            return evs
        t0 = self._q[0][0]
        while self._q and self._q[0][0] <= t0 + horizon and \
                (not max_size or len(evs) < max_size):
            t, cid = heapq.heappop(self._q)
            self.now = max(self.now, t)
            evs.append(Event(t, cid))
        return evs

    def peek_time(self) -> float:
        return self._q[0][0] if self._q else float("inf")

    def empty(self) -> bool:
        return not self._q

    def __len__(self) -> int:
        return len(self._q)


# ---------------------------------------------------------------------------
# pluggable event processes
# ---------------------------------------------------------------------------


class ArrivalProcess:
    """Maps a client's nominal cycle time to its next completion delay.

    The default is the identity — the paper's deterministic Table-I cost
    model.  Subclasses may hold an RNG; ``reset(seed)`` is called at the
    start of every ``run_async`` so that, for a fixed run seed, every
    engine draws the identical delay sequence (delays are requested once
    per event, in pop order, on all engines).
    """

    def reset(self, seed: int) -> None:
        pass

    def delay(self, cid: int, base: float) -> float:
        return base


class JitteredArrival(ArrivalProcess):
    """Lognormal multiplicative jitter on the nominal cycle time — the
    completion-time noise real fleets show (thermal throttling, contending
    apps, network variance)."""

    def __init__(self, sigma: float = 0.1):
        self.sigma = sigma
        self._rng = np.random.default_rng(0)

    def reset(self, seed: int) -> None:
        self._rng = np.random.default_rng((seed, 0xA221))

    def delay(self, cid: int, base: float) -> float:
        return base * float(self._rng.lognormal(0.0, self.sigma))


class DropoutProcess:
    """Decides, per completion event, whether the client's update is lost.

    A dropped completion contributes nothing to the global model (no
    training, no mixing, no snapshot) and the client retries after
    ``penalty`` times its next arrival delay.  Owns its own RNG stream so
    enabling dropout never perturbs arrival jitter draws.
    """

    penalty: float = 1.0

    def reset(self, seed: int) -> None:
        pass

    def drops(self, cid: int) -> bool:
        return False


class BernoulliDropout(DropoutProcess):
    """I.i.d. per-event drop with probability ``p``."""

    def __init__(self, p: float = 0.1, penalty: float = 1.0):
        self.p = p
        self.penalty = penalty
        self._rng = np.random.default_rng(0)

    def reset(self, seed: int) -> None:
        self._rng = np.random.default_rng((seed, 0xD809))

    def drops(self, cid: int) -> bool:
        return bool(self._rng.random() < self.p)
