from repro.federated.adapter import (CNNAdapter, FamilyAdapter,
                                     TokenLMAdapter, make_adapter)
from repro.federated.events import (ArrivalProcess, BernoulliDropout,
                                    DropoutProcess, Event, JitteredArrival,
                                    SimClock)
from repro.federated.heterogeneity import (CAPABLE, TABLE_I, cycle_time,
                                           make_fleet)
from repro.federated.runtime import (AsyncFLRun, BatchedFLRun, Client, FLRun,
                                     ShardedFLRun, setup_clients)
from repro.federated.schemes import SCHEMES, Scheme, make_scheme

__all__ = ["FLRun", "AsyncFLRun", "BatchedFLRun", "ShardedFLRun", "Client",
           "setup_clients", "make_fleet",
           "Scheme", "SCHEMES", "make_scheme",
           "cycle_time", "SimClock", "Event", "TABLE_I", "CAPABLE",
           "ArrivalProcess", "JitteredArrival", "DropoutProcess",
           "BernoulliDropout",
           "FamilyAdapter", "CNNAdapter", "TokenLMAdapter", "make_adapter"]
