from repro.federated.adapter import (CNNAdapter, FamilyAdapter,
                                     TokenLMAdapter, make_adapter)
from repro.federated.heterogeneity import (CAPABLE, TABLE_I, SimClock,
                                           cycle_time, make_fleet)
from repro.federated.runtime import (BatchedFLRun, Client, FLRun,
                                     ShardedFLRun, setup_clients)

__all__ = ["FLRun", "BatchedFLRun", "ShardedFLRun", "Client",
           "setup_clients", "make_fleet",
           "cycle_time", "SimClock", "TABLE_I", "CAPABLE",
           "FamilyAdapter", "CNNAdapter", "TokenLMAdapter", "make_adapter"]
