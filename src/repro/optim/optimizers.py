"""Optimizers from scratch (no optax in this container): SGD / momentum /
Adam / AdamW, LR schedules, global-norm clipping.

API mirrors the optax gradient-transformation convention:
  opt = adamw(lr_schedule, ...)
  state = opt.init(params)
  updates, state = opt.update(grads, state, params, step)
  params = apply_updates(params, updates)

Optimizer states inherit parameter shardings under pjit (same tree shape).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable        # (grads, state, params, step) -> (updates, state)


def _lr_at(lr, step):
    return lr(step) if callable(lr) else lr


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(peak_lr: float, warmup_steps: int,
                           total_steps: int, final_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) /
                        jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return sched


# ---------------------------------------------------------------------------
# Gradient utilities
# ---------------------------------------------------------------------------


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


def sgd(lr) -> Optimizer:
    def init(params):
        return {}

    def update(grads, state, params, step):
        lrv = _lr_at(lr, step)
        return jax.tree.map(lambda g: -lrv * g.astype(jnp.float32), grads), state

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)}

    def update(grads, state, params, step):
        m = jax.tree.map(lambda mm, g: beta * mm + g.astype(jnp.float32),
                         state["m"], grads)
        lrv = _lr_at(lr, step)
        return jax.tree.map(lambda mm: -lrv * mm, m), {"m": m}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0,
          mask_decay: Optional[Callable] = None) -> Optimizer:
    """AdamW.  ``mask_decay(path_free_leaf)`` can exempt leaves (norms, biases)
    from decay; by default 1-D leaves are exempt."""

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        step = jnp.asarray(step, jnp.float32) + 1.0
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** step
        bc2 = 1 - b2 ** step
        lrv = _lr_at(lr, step - 1)

        def upd(mm, vv, p):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
            decay = weight_decay if p.ndim >= 2 else 0.0
            return -lrv * (u + decay * p.astype(jnp.float32))

        return jax.tree.map(upd, m, v, params), {"m": m, "v": v}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    return adamw(lr, b1, b2, eps, weight_decay=0.0)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr, kw.get("beta", 0.9))
    if name == "adam":
        return adam(lr, kw.get("b1", 0.9), kw.get("b2", 0.999),
                    kw.get("eps", 1e-8))
    if name == "adamw":
        return adamw(lr, kw.get("b1", 0.9), kw.get("b2", 0.95),
                     kw.get("eps", 1e-8), kw.get("weight_decay", 0.1))
    raise ValueError(name)
