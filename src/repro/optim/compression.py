"""Uplink compression with error feedback (refs [19][20]) — the comms
companion of soft-training: soft-training shrinks the COMPUTE volume, the
codecs here shrink the COMMUNICATION volume (client -> server deltas) and
the MEMORY volume (the async snapshot ring's anchors), and Prop. 2's
variance bound is exactly the [19] analysis, so the two compose cleanly.

Three lossy modes, all differentiable-seam style (the engines thread a
``compression`` knob exactly like ``kernels``):

* ``topk``  — per-leaf magnitude top-k (k = max(1, round(frac*size)))
  with fp16 values on the wire (standard DGC practice [20]); the fp16
  rounding is absorbed by the error-feedback residual, so telescoping is
  exact by construction.
* ``quant`` — dense symmetric int-``bits`` quantization per leaf
  (scale = max|x| / (2^(bits-1)-1)); round-trip error <= scale/2.
* ``delta`` — top-k coordinates with int-``bits`` quantized values: the
  sparsity of ``topk`` at the value width of ``quant``.

Error feedback (Deep Gradient Compression, [20]): the un-sent residual is
accumulated per client and added to the next cycle's delta, which
empirically removes the convergence penalty of hard top-k.  Composed with
the Eq. 2 masks, frozen-neuron coordinates are never encoded or sent
(``compress_update(..., masks=...)`` zeroes them BEFORE encoding), but
their residual survives until the rotation wakes them.

Everything in :func:`compress_update` is shape-static (``jax.lax.top_k``
with a Python-int k) and vmap-safe, so a whole stacked cohort compresses
inside one jitted round/bucket program.  :class:`HostErrorStore` keeps the
per-client residuals HOST-resident (lazily materialized rows, like PR 3's
population state), so a million-client population only pays memory for
clients that have actually participated.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts as CT

#: the engine knob values (mirrors kernels="pallas"|"reference")
MODES = ("none", "topk", "quant", "delta")


def init_error(params):
    """Zero error-feedback accumulators, one per param leaf (param dtype —
    the residual lives in the same space as the update it absorbs)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)


def leaf_k(size: int, frac: float) -> int:
    """The per-leaf kept-coordinate count top-k actually uses."""
    return max(1, int(round(frac * size))) if size else 0


def _leaf_topk(x: jax.Array, frac: float) -> jax.Array:
    """Zero all but the top-``frac`` |values| of one leaf.

    Built on ``jax.lax.top_k`` over |x| with a STATIC k: O(n log k) and a
    fixed output shape, so the transform vmaps over a stacked cohort and
    never traces a ragged threshold (the old full ``jnp.sort`` was
    O(n log n) per leaf per client).
    """
    if x.size == 0:
        return x
    k = leaf_k(x.size, frac)
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def quantize(x: jax.Array, bits: int = 8) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-leaf quantization: (int codes, f32 scale).

    ``scale = max|x| / (2^(bits-1)-1)`` so every value is in range (no
    clipping error) and the round-trip error is <= scale/2.  Exact zeros
    encode as exact zeros — masked coordinates cost nothing downstream.
    """
    lim = float(2 ** (bits - 1) - 1)
    code_dtype = jnp.int8 if bits <= 8 else jnp.int32
    x = x.astype(jnp.float32)
    if x.size == 0:
        return jnp.zeros(x.shape, code_dtype), jnp.float32(1.0)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / lim
    q = jnp.clip(jnp.round(x / scale), -lim, lim)
    return q.astype(code_dtype), scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _roundtrip_quant(x: jax.Array, bits: int) -> jax.Array:
    q, s = quantize(x, bits)
    return dequantize(q, s)


def _roundtrip_f16(x: jax.Array) -> jax.Array:
    return x.astype(jnp.float16).astype(jnp.float32)


def compress_update(delta, error, mode: str, frac: float = 0.05,
                    bits: int = 8, masks=None):
    """Encode+decode one client->server update with error feedback.

    ``delta``: the raw update pytree (new_params - base), ``error``: this
    client's residual accumulator, ``masks``: optional param-shaped 0/1
    tree (expanded Eq. 2 masks) — masked coordinates are zeroed BEFORE
    encoding so they are never sent, while their residual persists.

    Returns ``(sent, new_error, sent_coords)``: the decoded update the
    server applies (what a real receiver reconstructs from the wire
    format), the residual to keep client-side, and the encoded-coordinate
    count (a device scalar; no host sync).  Telescoping holds exactly:
    ``sent + new_error == delta + error`` on unmasked coordinates.
    """
    if mode not in MODES or mode == "none":
        raise ValueError(f"compress_update: bad mode {mode!r}")
    corrected = jax.tree.map(
        lambda d, e: d.astype(jnp.float32) + e.astype(jnp.float32),
        delta, error)
    avail = corrected if masks is None else \
        jax.tree.map(lambda c, m: c * m, corrected, masks)
    if mode == "topk":
        sent = jax.tree.map(lambda a: _roundtrip_f16(_leaf_topk(a, frac)),
                            avail)
    elif mode == "delta":
        sent = jax.tree.map(
            lambda a: _roundtrip_quant(_leaf_topk(a, frac), bits), avail)
    else:                                                  # quant (dense)
        sent = jax.tree.map(lambda a: _roundtrip_quant(a, bits), avail)
    new_error = jax.tree.map(lambda c, s, e: (c - s).astype(e.dtype),
                             corrected, sent, error)
    if mode == "quant":
        # dense wire format: every unmasked coordinate is encoded, sent or
        # not — count mask coverage, not nonzeros
        if masks is None:
            coords = jnp.float32(sum(l.size for l in jax.tree.leaves(sent)))
        else:
            coords = sum(jnp.sum(m) for m in jax.tree.leaves(masks))
    else:
        coords = sum(jnp.sum(s != 0).astype(jnp.float32)
                     for s in jax.tree.leaves(sent))
    return sent, new_error, coords


def uplink_bytes(mode: str, coords: float, total: int, n_leaves: int,
                 bits: int = 8, index_bytes: int = 4) -> float:
    """Wire bytes for ``coords`` encoded coordinates in one update.

    * none  — dense f32, everything moves.
    * topk  — (index, fp16 value) per kept coordinate.
    * quant — ``bits``-bit code per encoded coordinate + one f32 scale per
      leaf (dense: no indices).
    * delta — (index, ``bits``-bit value) per kept coordinate + scales.
    """
    if mode == "none":
        return float(total) * 4.0
    if mode == "topk":
        return coords * (index_bytes + 2.0)
    if mode == "quant":
        return coords * bits / 8.0 + n_leaves * 4.0
    if mode == "delta":
        return coords * (index_bytes + bits / 8.0) + n_leaves * 4.0
    raise ValueError(mode)


def compress(grads, error, frac: float) -> Tuple[dict, dict, jax.Array]:
    """Legacy 3-tuple top-k API: (sparse_grads, new_error, sent_fraction).

    Full-precision values (no wire rounding) — kept for callers that use
    the sparsifier as an optimizer transform rather than a wire codec.
    """
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, error)
    sparse = jax.tree.map(lambda c: _leaf_topk(c, frac), corrected)
    new_error = jax.tree.map(lambda c, s: c - s, corrected, sparse)
    total = sum(l.size for l in jax.tree.leaves(sparse))
    nnz = sum(jnp.sum(l != 0) for l in jax.tree.leaves(sparse))
    return sparse, new_error, nnz / max(total, 1)


def compressed_bytes(grads, frac: float, index_bytes: int = 4,
                     value_bytes: int = 4) -> int:
    """Uplink bytes for a top-k sparse encoding (index+value per coord).

    Accounts per LEAF — ``k = max(1, round(frac*size))`` summed over
    leaves — matching what :func:`compress`/:func:`compress_update`
    actually keep (a single global round() disagrees with the per-leaf
    floors whenever small leaves are present).
    """
    k = sum(leaf_k(l.size, frac) for l in jax.tree.leaves(grads))
    return k * (index_bytes + value_bytes)


class HostErrorStore:
    """Host-resident error-feedback state, one lazily-materialized row per
    client.

    The stacked-cohort engines gather the cohort's rows to device each
    round and scatter the updated residuals back (the same host-resident
    pattern as ``soft_train.host_states``: host arrays are uncommitted jit
    inputs, so the round program's input signature is draw-invariant).
    Rows exist only for clients that have actually been scattered to —
    at N=10^6 with K clients/round the store grows with participation
    coverage, not the population, which is what keeps the million-client
    bench inside its host-memory budget.
    """

    def __init__(self, params):
        # one shared zero row (copied on gather by np.stack) — absent
        # clients read as zero residual without N materialized rows
        self._zero = jax.tree.map(
            lambda p: np.zeros(p.shape, np.dtype(p.dtype)), params)
        self._rows: Dict[int, dict] = {}

    def gather(self, cids: Sequence[int]) -> dict:
        """Stacked (len(cids),)+shape rows; untouched clients read zeros."""
        rows = [self._rows.get(int(c), self._zero) for c in cids]
        return jax.tree.map(lambda *xs: np.stack(xs), *rows)

    def scatter(self, cids: Sequence[int], stacked) -> None:
        """Write rows back (``cids`` duplicate-free; device leaves pulled
        host-side — an INTENDED transfer, like the population scatter)."""
        with CT.expected_transfer("compression.error_store.scatter"):
            host = jax.tree.map(np.asarray, stacked)
        for i, c in enumerate(cids):
            self._rows[int(c)] = jax.tree.map(lambda x: np.array(x[i]), host)

    def row(self, cid: int) -> dict:
        """One client's residual (host leaves; zeros if never touched)."""
        return self._rows.get(int(cid), self._zero)

    def set_row(self, cid: int, tree) -> None:
        with CT.expected_transfer("compression.error_store.scatter"):
            self._rows[int(cid)] = jax.tree.map(np.asarray, tree)

    def touched(self) -> int:
        return len(self._rows)

    def nbytes(self) -> int:
        return sum(x.nbytes for r in self._rows.values()
                   for x in jax.tree.leaves(r))

    def stats(self) -> Dict[str, int]:
        """Store census for the telemetry sinks (repro.obs): materialized
        client rows + host bytes they hold."""
        return {"rows": self.touched(), "bytes": self.nbytes()}


def param_census(params) -> Tuple[int, int]:
    """(total scalar count, leaf count) — the uplink-bytes denominators."""
    leaves = jax.tree.leaves(params)
    return sum(l.size for l in leaves), len(leaves)
