"""Top-k gradient sparsification with error feedback (refs [19][20]).

# repro: noqa[R6] — tests-only today: wired into the FL uplink when the
communication-volume experiments land (tracked in ROADMAP.md).

Used on the FL uplink (client -> server) as the distributed-optimization
companion of soft-training: soft-training shrinks the COMPUTE volume, top-k
compression shrinks the COMMUNICATION volume, and Prop. 2's variance bound is
exactly the [19] analysis, so the two compose cleanly.

Error feedback (Deep Gradient Compression, [20]): the un-sent residual is
accumulated locally and added to the next cycle's gradient, which empirically
removes the convergence penalty of hard top-k.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _leaf_topk(x: jax.Array, frac: float) -> jax.Array:
    """Zero all but the top-``frac`` |values| of one leaf."""
    if x.size == 0:
        return x
    k = max(1, int(round(frac * x.size)))
    flat = jnp.abs(x.reshape(-1))
    thresh = jnp.sort(flat)[-k]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def compress(grads, error, frac: float) -> Tuple[dict, dict, jax.Array]:
    """Returns (sparse_grads, new_error, sent_fraction)."""
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, error)
    sparse = jax.tree.map(lambda c: _leaf_topk(c, frac), corrected)
    new_error = jax.tree.map(lambda c, s: c - s, corrected, sparse)
    total = sum(l.size for l in jax.tree.leaves(sparse))
    nnz = sum(jnp.sum(l != 0) for l in jax.tree.leaves(sparse))
    return sparse, new_error, nnz / max(total, 1)


def compressed_bytes(grads, frac: float, index_bytes: int = 4,
                     value_bytes: int = 4) -> int:
    """Uplink bytes for a top-k sparse encoding (index+value per coord)."""
    total = sum(l.size for l in jax.tree.leaves(grads))
    k = int(round(frac * total))
    return k * (index_bytes + value_bytes)
