from repro.optim.optimizers import (Optimizer, adam, adamw, apply_updates,
                                    clip_by_global_norm, constant_schedule,
                                    global_norm, make_optimizer, momentum,
                                    sgd, warmup_cosine_schedule)
from repro.optim import compression

__all__ = ["Optimizer", "sgd", "momentum", "adam", "adamw", "make_optimizer",
           "apply_updates", "clip_by_global_norm", "global_norm",
           "constant_schedule", "warmup_cosine_schedule", "compression"]
