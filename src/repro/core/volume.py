"""Optimization-target determination (Section IV.C) + dynamic adaptation.

Two paths, as in the paper:

* ``assign_volume_levels`` — pre-defined volume levels assigned by the
  time-cost ranking index T (black-box deployments); refined online by
  ``adapt_volume`` during the first cycles.
* ``volume_from_profile`` — white-box: pick P so the modeled cycle time of
  the compressed model matches the collaboration pace.  Soft-training FLOPs
  scale ~linearly in P (both matmuls of a masked hidden unit vanish), so the
  first-order solve is P = pace / straggler_time, then the controller
  corrects any modeling error.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def assign_volume_levels(time_costs: Sequence[float],
                         levels: Sequence[float],
                         num_stragglers: int) -> list[float]:
    """Rank devices by time cost (T index); top-k stragglers get levels.

    The slowest straggler gets the smallest volume level.  Non-stragglers
    get 1.0.
    """
    order = np.argsort(np.asarray(time_costs))[::-1]       # slowest first
    lv = sorted(levels)                                     # ascending
    out = [1.0] * len(time_costs)
    for rank, dev in enumerate(order[:num_stragglers]):
        out[dev] = lv[min(rank, len(lv) - 1)]
    return out


def volume_from_profile(straggler_time: float, pace_time: float,
                        min_volume: float = 0.125) -> float:
    """White-box target: modeled time scales ~P -> P = pace / time."""
    if straggler_time <= pace_time:
        return 1.0
    return float(np.clip(pace_time / straggler_time, min_volume, 1.0))


def adapt_volume(volume: float, observed_time: float, deadline: float,
                 gain: float = 0.5, min_volume: float = 0.125) -> float:
    """Multiplicative controller: move P toward the deadline match.

    P_new = P * (deadline / observed)^gain — gain < 1 damps oscillation
    (the paper adjusts "during the first several training cycles").
    """
    if observed_time <= 0:
        return volume
    ratio = deadline / observed_time
    return float(np.clip(volume * ratio ** gain, min_volume, 1.0))
