"""Heterogeneous model aggregation (Section VI.B, Eq. 10) + variants.

* ``alpha_weighted`` (paper): client n is weighted alpha_n = r_n / sum(r_m),
  r_n = its selected-neuron ratio — a more complete sub-model contributes
  more.
* ``masked_mean`` (beyond-paper): per-COORDINATE weighted mean over the
  clients that actually trained each coordinate; coordinates nobody trained
  keep the previous global value.  Removes the bias the model-level alpha
  weighting leaves on units trained by few clients.
* ``uniform``: plain FedAvg (the Syn./Asyn. FL baselines).

All functions operate on pytrees and are jit-friendly; in the datacenter
mapping the same weighted mean is a single all-reduce over the client mesh
axis (launch/train.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp


def alpha_weights(ratios: Sequence[float]) -> jnp.ndarray:
    r = jnp.asarray(ratios, jnp.float32)
    return r / jnp.maximum(jnp.sum(r), 1e-9)


def aggregate_alpha(global_params, client_params: Sequence,
                    ratios: Sequence[float]):
    """Eq. 10: theta = sum_n alpha_n theta_n."""
    a = alpha_weights(ratios)

    def combine(*leaves):
        g = leaves[0]
        acc = jnp.zeros_like(g, jnp.float32)
        for w, leaf in zip(a, leaves[1:]):
            acc = acc + w * leaf.astype(jnp.float32)
        return acc.astype(g.dtype)

    return jax.tree.map(combine, global_params, *client_params)


def aggregate_masked_mean(global_params, client_params: Sequence,
                          client_masks: Sequence,
                          ratios: Optional[Sequence[float]] = None):
    """Per-coordinate mean over clients whose mask covers the coordinate.

    client_masks: param-shaped 0/1 trees (core.masking.expand_masks).
    Optionally alpha-weighted within the covered set.
    """
    n = len(client_params)
    a = alpha_weights(ratios) if ratios is not None else \
        jnp.full((n,), 1.0 / n, jnp.float32)

    def combine(g, *mp):
        masks = mp[:n]
        thetas = mp[n:]
        num = jnp.zeros_like(g, jnp.float32)
        den = jnp.zeros_like(g, jnp.float32)
        for w, m, t in zip(a, masks, thetas):
            num = num + w * m * t.astype(jnp.float32)
            den = den + w * m
        return jnp.where(den > 0, num / jnp.maximum(den, 1e-9),
                         g.astype(jnp.float32)).astype(g.dtype)

    return jax.tree.map(combine, global_params, *client_masks, *client_params)


def aggregate_uniform(global_params, client_params: Sequence):
    return aggregate_alpha(global_params, client_params,
                           [1.0] * len(client_params))


# ---------------------------------------------------------------------------
# stacked (batched-client) variants: client_params leaves carry a leading
# client axis (C, ...) — the whole aggregation fuses into one tensordot /
# masked reduction per leaf instead of a Python loop over a list of pytrees.
# In the datacenter mapping the client axis is the pod mesh axis and the
# reduction compiles to a single all-reduce.
# ---------------------------------------------------------------------------


def aggregate_alpha_stacked(global_params, stacked_params, ratios):
    """Eq. 10 over a stacked client axis.  ratios: (C,) selected fractions."""
    a = alpha_weights(ratios)
    return jax.tree.map(
        lambda g, t: jnp.tensordot(a, t.astype(jnp.float32),
                                   axes=1).astype(g.dtype),
        global_params, stacked_params)


def aggregate_uniform_stacked(global_params, stacked_params):
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    return aggregate_alpha_stacked(global_params, stacked_params,
                                   jnp.ones((n,), jnp.float32))


def aggregate_masked_mean_stacked(global_params, stacked_params,
                                  stacked_masks,
                                  ratios: Optional[jax.Array] = None):
    """Per-coordinate weighted mean over the stacked client axis.

    stacked_masks: params-shaped 0/1 trees with leaves (C,) + param.shape
    (masking.cnn_expand_masks_batch / vmapped expand_masks).
    """
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    a = alpha_weights(ratios) if ratios is not None else \
        jnp.full((n,), 1.0 / n, jnp.float32)

    def combine(g, m, t):
        w = a.reshape((n,) + (1,) * g.ndim)
        num = jnp.sum(w * m * t.astype(jnp.float32), axis=0)
        den = jnp.sum(w * m, axis=0)
        return jnp.where(den > 0, num / jnp.maximum(den, 1e-9),
                         g.astype(jnp.float32)).astype(g.dtype)

    return jax.tree.map(combine, global_params, stacked_masks, stacked_params)


def aggregate_stacked(cfg_mode: str, global_params, stacked_params,
                      ratios=None, stacked_masks=None):
    if cfg_mode == "alpha_weighted":
        return aggregate_alpha_stacked(global_params, stacked_params, ratios)
    if cfg_mode == "masked_mean":
        return aggregate_masked_mean_stacked(global_params, stacked_params,
                                             stacked_masks, ratios)
    if cfg_mode == "uniform":
        return aggregate_uniform_stacked(global_params, stacked_params)
    raise ValueError(cfg_mode)


def staleness_weight(staleness: int, a: float = 0.5) -> float:
    """AFO (Xie et al. 2019) polynomial staleness discount (t - tau + 1)^-a."""
    return float((staleness + 1.0) ** (-a))


def mix(global_params, client_params, weight: float):
    """Async mixing: theta <- (1-w) theta + w theta_client (AFO/Asyn paths)."""
    return jax.tree.map(
        lambda g, c: ((1 - weight) * g.astype(jnp.float32)
                      + weight * c.astype(jnp.float32)).astype(g.dtype),
        global_params, client_params)


def aggregate(cfg_mode: str, global_params, client_params,
              ratios=None, client_masks=None):
    if cfg_mode == "alpha_weighted":
        return aggregate_alpha(global_params, client_params, ratios)
    if cfg_mode == "masked_mean":
        return aggregate_masked_mean(global_params, client_params,
                                     client_masks, ratios)
    if cfg_mode == "uniform":
        return aggregate_uniform(global_params, client_params)
    raise ValueError(cfg_mode)
