"""Heterogeneous model aggregation (Section VI.B, Eq. 10) + variants.

* ``alpha_weighted`` (paper): client n is weighted alpha_n = r_n / sum(r_m),
  r_n = its selected-neuron ratio — a more complete sub-model contributes
  more.
* ``masked_mean`` (beyond-paper): per-COORDINATE weighted mean over the
  clients that actually trained each coordinate; coordinates nobody trained
  keep the previous global value.  Removes the bias the model-level alpha
  weighting leaves on units trained by few clients.
* ``uniform``: plain FedAvg (the Syn./Asyn. FL baselines).

All functions operate on pytrees and are jit-friendly; in the datacenter
mapping the same weighted mean is a single all-reduce over the client mesh
axis (launch/train.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts as CT


def _finite_out(out, *args, **kwargs):
    """Aggregation contract: the merged globals carry no NaN/Inf — one
    poisoned client update must trip here, at the seam, not rounds later
    in a diverged trajectory.  No-op on traced values and with contracts
    off."""
    CT.assert_finite(out, tag="aggregation")


def alpha_weights(ratios: Sequence[float]) -> jnp.ndarray:
    r = jnp.asarray(ratios, jnp.float32)
    return r / jnp.maximum(jnp.sum(r), 1e-9)


def aggregate_alpha(global_params, client_params: Sequence,
                    ratios: Sequence[float]):
    """Eq. 10: theta = sum_n alpha_n theta_n."""
    a = alpha_weights(ratios)

    def combine(*leaves):
        g = leaves[0]
        acc = jnp.zeros_like(g, jnp.float32)
        for w, leaf in zip(a, leaves[1:]):
            acc = acc + w * leaf.astype(jnp.float32)
        return acc.astype(g.dtype)

    return jax.tree.map(combine, global_params, *client_params)


def aggregate_masked_mean(global_params, client_params: Sequence,
                          client_masks: Sequence,
                          ratios: Optional[Sequence[float]] = None):
    """Per-coordinate mean over clients whose mask covers the coordinate.

    client_masks: param-shaped 0/1 trees (core.masking.expand_masks).
    Optionally alpha-weighted within the covered set.
    """
    n = len(client_params)
    a = alpha_weights(ratios) if ratios is not None else \
        jnp.full((n,), 1.0 / n, jnp.float32)

    def combine(g, *mp):
        masks = mp[:n]
        thetas = mp[n:]
        num = jnp.zeros_like(g, jnp.float32)
        den = jnp.zeros_like(g, jnp.float32)
        for w, m, t in zip(a, masks, thetas):
            num = num + w * m * t.astype(jnp.float32)
            den = den + w * m
        return jnp.where(den > 0, num / jnp.maximum(den, 1e-9),
                         g.astype(jnp.float32)).astype(g.dtype)

    return jax.tree.map(combine, global_params, *client_masks, *client_params)


def aggregate_uniform(global_params, client_params: Sequence):
    return aggregate_alpha(global_params, client_params,
                           [1.0] * len(client_params))


# ---------------------------------------------------------------------------
# stacked (batched-client) variants: client_params leaves carry a leading
# client axis (C, ...) — the whole aggregation fuses into one tensordot /
# masked reduction per leaf instead of a Python loop over a list of pytrees.
# In the datacenter mapping the client axis is the pod mesh axis and the
# reduction compiles to a single all-reduce.
# ---------------------------------------------------------------------------


def aggregate_alpha_stacked(global_params, stacked_params, ratios):
    """Eq. 10 over a stacked client axis.  ratios: (C,) selected fractions."""
    a = alpha_weights(ratios)
    return jax.tree.map(
        lambda g, t: jnp.tensordot(a, t.astype(jnp.float32),
                                   axes=1).astype(g.dtype),
        global_params, stacked_params)


def aggregate_uniform_stacked(global_params, stacked_params):
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    return aggregate_alpha_stacked(global_params, stacked_params,
                                   jnp.ones((n,), jnp.float32))


def aggregate_masked_mean_stacked(global_params, stacked_params,
                                  stacked_masks,
                                  ratios: Optional[jax.Array] = None):
    """Per-coordinate weighted mean over the stacked client axis.

    stacked_masks: params-shaped 0/1 trees with leaves (C,) + param.shape
    (masking.cnn_expand_masks_batch / vmapped expand_masks).
    """
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    a = alpha_weights(ratios) if ratios is not None else \
        jnp.full((n,), 1.0 / n, jnp.float32)

    def combine(g, m, t):
        w = a.reshape((n,) + (1,) * g.ndim)
        num = jnp.sum(w * m * t.astype(jnp.float32), axis=0)
        den = jnp.sum(w * m, axis=0)
        return jnp.where(den > 0, num / jnp.maximum(den, 1e-9),
                         g.astype(jnp.float32)).astype(g.dtype)

    return jax.tree.map(combine, global_params, stacked_masks, stacked_params)


@CT.contract(post=_finite_out)
def aggregate_stacked(cfg_mode: str, global_params, stacked_params,
                      ratios=None, stacked_masks=None):
    if cfg_mode == "alpha_weighted":
        return aggregate_alpha_stacked(global_params, stacked_params, ratios)
    if cfg_mode == "masked_mean":
        return aggregate_masked_mean_stacked(global_params, stacked_params,
                                             stacked_masks, ratios)
    if cfg_mode == "uniform":
        return aggregate_uniform_stacked(global_params, stacked_params)
    raise ValueError(cfg_mode)


def staleness_weight(staleness: int, a: float = 0.5) -> float:
    """AFO (Xie et al. 2019) polynomial staleness discount (t - tau + 1)^-a."""
    return float((staleness + 1.0) ** (-a))


def staleness_weights(staleness: jax.Array, a=0.5) -> jax.Array:
    """Vectorized :func:`staleness_weight` — traced inside the bucketed
    async program so per-event AFO discounts cost no host round-trip."""
    return (staleness.astype(jnp.float32) + 1.0) ** (-a)


@CT.contract(post=_finite_out)
def mix(global_params, client_params, weight: float):
    """Async mixing: theta <- (1-w) theta + w theta_client (AFO/Asyn paths)."""
    return jax.tree.map(
        lambda g, c: ((1 - weight) * g.astype(jnp.float32)
                      + weight * c.astype(jnp.float32)).astype(g.dtype),
        global_params, client_params)


def mix_bucket(global_params, stacked_params, weights):
    """Sequentially :func:`mix` a bucket of client params into the global.

    ``stacked_params`` leaves carry a leading (B,) event axis; ``weights``
    is the (B,) per-event mixing weight (already staleness-discounted /
    zeroed on padding slots).  The fold runs in bucket order under one
    ``lax.scan`` — exactly the event-loop semantics, traced as one program
    instead of B host dispatches.  ``w_i = 0`` leaves the global untouched.
    """
    def step(g, x):
        p, w = x
        g = jax.tree.map(
            lambda gg, pp: ((1 - w) * gg.astype(jnp.float32)
                            + w * pp.astype(jnp.float32)).astype(gg.dtype),
            g, p)
        return g, None

    g, _ = jax.lax.scan(step, global_params, (stacked_params, weights))
    return g


def mix_bucket_ring(global_params, ring_params, slots, stacked_params,
                    weights):
    """:func:`mix_bucket` that also snapshots every intermediate global.

    After event i's mix the new global is written to ring row ``slots[i]``
    (a :class:`SnapshotRing` buffer) — the device-side replacement for the
    per-event Python-dict snapshot the sequential async loop keeps.  Point
    a padding slot at the ring's scratch row: its weight is 0, so it writes
    back an unchanged global nobody reads.  Returns (global, ring_params).
    """
    def step(carry, x):
        g, ring = carry
        p, w, s = x
        g = jax.tree.map(
            lambda gg, pp: ((1 - w) * gg.astype(jnp.float32)
                            + w * pp.astype(jnp.float32)).astype(gg.dtype),
            g, p)
        ring = jax.tree.map(lambda r, gg: r.at[s].set(gg), ring, g)
        return (g, ring), None

    (g, ring), _ = jax.lax.scan(step, (global_params, ring_params),
                                (stacked_params, weights, slots))
    return g, ring


# ---------------------------------------------------------------------------
# snapshot ring buffer (bucketed async engine)
# ---------------------------------------------------------------------------


class RingAllocator:
    """Anchor-aware slot allocator for a fixed ring of snapshot rows.

    Host-side bookkeeping only (the rows themselves live in
    :class:`SnapshotRing`).  Each snapshot is identified by its aggregation
    id (the global mix counter at creation); clients "anchor" the id they
    last pulled from via retain/release refcounts.  Allocation reuses the
    oldest slot with refcount 0 — so a live anchor is NEVER evicted, the
    invariant the sequential engine's dict eviction maintains by scanning.
    The last slot is reserved scratch (padding writes land there).
    """

    def __init__(self, slots: int):
        assert slots >= 2, "need at least one data slot + scratch"
        self.slots = slots
        self._slot_agg = np.full(slots, -1, np.int64)
        self._refcnt = np.zeros(slots, np.int64)
        self._agg_slot: Dict[int, int] = {}
        self.anchor_misses = 0
        self.peak_live = 0

    @property
    def scratch(self) -> int:
        return self.slots - 1

    def seed(self, agg: int, slot: int = 0) -> None:
        """Install the initial snapshot id into a slot."""
        self._slot_agg[slot] = agg
        self._agg_slot[agg] = slot

    def slot_of(self, agg: int) -> int:
        s = self._agg_slot.get(agg)
        if s is None:
            # an anchored snapshot was evicted — the invariant the
            # allocator exists to uphold; surface loudly
            self.anchor_misses += 1
            raise KeyError(f"snapshot {agg} evicted while still anchored")
        return s

    def retain(self, agg: int) -> None:
        self._refcnt[self.slot_of(agg)] += 1
        self.peak_live = max(self.peak_live,
                             int(np.count_nonzero(self._refcnt)))

    def release(self, agg: int) -> None:
        s = self.slot_of(agg)
        assert self._refcnt[s] > 0, f"release of unanchored snapshot {agg}"
        self._refcnt[s] -= 1

    def alloc(self, agg: int) -> int:
        """Slot for a NEW snapshot ``agg``: the oldest unanchored data slot
        (never scratch, never a slot some client still reads through)."""
        free = np.where(self._refcnt[:-1] == 0)[0]
        if free.size == 0:
            raise RuntimeError(
                f"snapshot ring full: all {self.slots - 1} data slots are "
                "anchored (ring must be sized >= live anchors + 1)")
        s = int(free[np.argmin(self._slot_agg[free])])
        old = int(self._slot_agg[s])
        if old >= 0:
            del self._agg_slot[old]
        self._slot_agg[s] = agg
        self._agg_slot[agg] = s
        return s

    def live_slots(self) -> int:
        return int(np.count_nonzero(self._refcnt))


class SnapshotRing:
    """Device-side stacked snapshot store for the bucketed async engine.

    ``params`` is one pytree whose leaves carry a leading (slots,) axis —
    row r holds the global params as of some aggregation step.  Reads are a
    traced ``jnp.take`` over the bucket's anchor rows and writes happen
    inside the bucket program (:func:`mix_bucket_ring`), so per-event
    snapshotting never leaves the device.  Slot lifetime is managed by the
    host-side :class:`RingAllocator`; capacity is ``max(cap, anchors + 1)``
    data slots + 1 scratch, which by construction bounds the store the same
    way the sequential dict bounds itself (cap + live anchors).
    """

    def __init__(self, params, cap: int, n_anchors: int):
        self.alloc = RingAllocator(max(cap, n_anchors + 1) + 1)
        self.params = jax.tree.map(
            lambda x: jnp.zeros((self.alloc.slots,) + x.shape,
                                x.dtype).at[0].set(x), params)
        self.alloc.seed(0, slot=0)

    @property
    def scratch(self) -> int:
        return self.alloc.scratch

    def read(self, agg: int):
        """Materialize snapshot ``agg`` (tests / inspection)."""
        s = self.alloc.slot_of(agg)
        return jax.tree.map(lambda x: x[s], self.params)


@CT.contract(post=_finite_out)
def aggregate(cfg_mode: str, global_params, client_params,
              ratios=None, client_masks=None):
    if cfg_mode == "alpha_weighted":
        return aggregate_alpha(global_params, client_params, ratios)
    if cfg_mode == "masked_mean":
        return aggregate_masked_mean(global_params, client_params,
                                     client_masks, ratios)
    if cfg_mode == "uniform":
        return aggregate_uniform(global_params, client_params)
    raise ValueError(cfg_mode)
