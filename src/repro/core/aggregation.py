"""Heterogeneous model aggregation (Section VI.B, Eq. 10) + variants.

* ``alpha_weighted`` (paper): client n is weighted alpha_n = r_n / sum(r_m),
  r_n = its selected-neuron ratio — a more complete sub-model contributes
  more.
* ``masked_mean`` (beyond-paper): per-COORDINATE weighted mean over the
  clients that actually trained each coordinate; coordinates nobody trained
  keep the previous global value.  Removes the bias the model-level alpha
  weighting leaves on units trained by few clients.
* ``uniform``: plain FedAvg (the Syn./Asyn. FL baselines).

All functions operate on pytrees and are jit-friendly; in the datacenter
mapping the same weighted mean is a single all-reduce over the client mesh
axis (launch/train.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts as CT
from repro.optim import compression as CP


def _finite_out(out, *args, **kwargs):
    """Aggregation contract: the merged globals carry no NaN/Inf — one
    poisoned client update must trip here, at the seam, not rounds later
    in a diverged trajectory.  No-op on traced values and with contracts
    off."""
    CT.assert_finite(out, tag="aggregation")


def alpha_weights(ratios: Sequence[float]) -> jnp.ndarray:
    r = jnp.asarray(ratios, jnp.float32)
    return r / jnp.maximum(jnp.sum(r), 1e-9)


def aggregate_alpha(global_params, client_params: Sequence,
                    ratios: Sequence[float]):
    """Eq. 10: theta = sum_n alpha_n theta_n."""
    a = alpha_weights(ratios)

    def combine(*leaves):
        g = leaves[0]
        acc = jnp.zeros_like(g, jnp.float32)
        for w, leaf in zip(a, leaves[1:]):
            acc = acc + w * leaf.astype(jnp.float32)
        return acc.astype(g.dtype)

    return jax.tree.map(combine, global_params, *client_params)


def aggregate_masked_mean(global_params, client_params: Sequence,
                          client_masks: Sequence,
                          ratios: Optional[Sequence[float]] = None):
    """Per-coordinate mean over clients whose mask covers the coordinate.

    client_masks: param-shaped 0/1 trees (core.masking.expand_masks).
    Optionally alpha-weighted within the covered set.
    """
    n = len(client_params)
    a = alpha_weights(ratios) if ratios is not None else \
        jnp.full((n,), 1.0 / n, jnp.float32)

    def combine(g, *mp):
        masks = mp[:n]
        thetas = mp[n:]
        num = jnp.zeros_like(g, jnp.float32)
        den = jnp.zeros_like(g, jnp.float32)
        for w, m, t in zip(a, masks, thetas):
            num = num + w * m * t.astype(jnp.float32)
            den = den + w * m
        return jnp.where(den > 0, num / jnp.maximum(den, 1e-9),
                         g.astype(jnp.float32)).astype(g.dtype)

    return jax.tree.map(combine, global_params, *client_masks, *client_params)


def aggregate_uniform(global_params, client_params: Sequence):
    return aggregate_alpha(global_params, client_params,
                           [1.0] * len(client_params))


# ---------------------------------------------------------------------------
# stacked (batched-client) variants: client_params leaves carry a leading
# client axis (C, ...) — the whole aggregation fuses into one tensordot /
# masked reduction per leaf instead of a Python loop over a list of pytrees.
# In the datacenter mapping the client axis is the pod mesh axis and the
# reduction compiles to a single all-reduce.
# ---------------------------------------------------------------------------


def aggregate_alpha_stacked(global_params, stacked_params, ratios):
    """Eq. 10 over a stacked client axis.  ratios: (C,) selected fractions."""
    a = alpha_weights(ratios)
    return jax.tree.map(
        lambda g, t: jnp.tensordot(a, t.astype(jnp.float32),
                                   axes=1).astype(g.dtype),
        global_params, stacked_params)


def aggregate_uniform_stacked(global_params, stacked_params):
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    return aggregate_alpha_stacked(global_params, stacked_params,
                                   jnp.ones((n,), jnp.float32))


def aggregate_masked_mean_stacked(global_params, stacked_params,
                                  stacked_masks,
                                  ratios: Optional[jax.Array] = None):
    """Per-coordinate weighted mean over the stacked client axis.

    stacked_masks: params-shaped 0/1 trees with leaves (C,) + param.shape
    (masking.cnn_expand_masks_batch / vmapped expand_masks).
    """
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    a = alpha_weights(ratios) if ratios is not None else \
        jnp.full((n,), 1.0 / n, jnp.float32)

    def combine(g, m, t):
        w = a.reshape((n,) + (1,) * g.ndim)
        num = jnp.sum(w * m * t.astype(jnp.float32), axis=0)
        den = jnp.sum(w * m, axis=0)
        return jnp.where(den > 0, num / jnp.maximum(den, 1e-9),
                         g.astype(jnp.float32)).astype(g.dtype)

    return jax.tree.map(combine, global_params, stacked_masks, stacked_params)


@CT.contract(post=_finite_out)
def aggregate_stacked(cfg_mode: str, global_params, stacked_params,
                      ratios=None, stacked_masks=None):
    if cfg_mode == "alpha_weighted":
        return aggregate_alpha_stacked(global_params, stacked_params, ratios)
    if cfg_mode == "masked_mean":
        return aggregate_masked_mean_stacked(global_params, stacked_params,
                                             stacked_masks, ratios)
    if cfg_mode == "uniform":
        return aggregate_uniform_stacked(global_params, stacked_params)
    raise ValueError(cfg_mode)


def staleness_weight(staleness: int, a: float = 0.5) -> float:
    """AFO (Xie et al. 2019) polynomial staleness discount (t - tau + 1)^-a."""
    return float((staleness + 1.0) ** (-a))


def staleness_weights(staleness: jax.Array, a=0.5) -> jax.Array:
    """Vectorized :func:`staleness_weight` — traced inside the bucketed
    async program so per-event AFO discounts cost no host round-trip."""
    return (staleness.astype(jnp.float32) + 1.0) ** (-a)


@CT.contract(post=_finite_out)
def mix(global_params, client_params, weight: float):
    """Async mixing: theta <- (1-w) theta + w theta_client (AFO/Asyn paths)."""
    return jax.tree.map(
        lambda g, c: ((1 - weight) * g.astype(jnp.float32)
                      + weight * c.astype(jnp.float32)).astype(g.dtype),
        global_params, client_params)


def mix_bucket(global_params, stacked_params, weights):
    """Sequentially :func:`mix` a bucket of client params into the global.

    ``stacked_params`` leaves carry a leading (B,) event axis; ``weights``
    is the (B,) per-event mixing weight (already staleness-discounted /
    zeroed on padding slots).  The fold runs in bucket order under one
    ``lax.scan`` — exactly the event-loop semantics, traced as one program
    instead of B host dispatches.  ``w_i = 0`` leaves the global untouched.
    """
    def step(g, x):
        p, w = x
        g = jax.tree.map(
            lambda gg, pp: ((1 - w) * gg.astype(jnp.float32)
                            + w * pp.astype(jnp.float32)).astype(gg.dtype),
            g, p)
        return g, None

    g, _ = jax.lax.scan(step, global_params, (stacked_params, weights))
    return g


def mix_bucket_ring(global_params, ring_params, slots, stacked_params,
                    weights):
    """:func:`mix_bucket` that also snapshots every intermediate global.

    After event i's mix the new global is written to ring row ``slots[i]``
    (a :class:`SnapshotRing` buffer) — the device-side replacement for the
    per-event Python-dict snapshot the sequential async loop keeps.  Point
    a padding slot at the ring's scratch row: its weight is 0, so it writes
    back an unchanged global nobody reads.  Returns (global, ring_params).
    """
    def step(carry, x):
        g, ring = carry
        p, w, s = x
        g = jax.tree.map(
            lambda gg, pp: ((1 - w) * gg.astype(jnp.float32)
                            + w * pp.astype(jnp.float32)).astype(gg.dtype),
            g, p)
        ring = jax.tree.map(lambda r, gg: r.at[s].set(gg), ring, g)
        return (g, ring), None

    (g, ring), _ = jax.lax.scan(step, (global_params, ring_params),
                                (stacked_params, weights, slots))
    return g, ring


def _lossy_delta(leaf, ref_leaf):
    """Encode-space view of a snapshot leaf: vs the fixed reference (delta
    mode) or the raw value (quant mode, ``ref_leaf`` None)."""
    x = leaf.astype(jnp.float32)
    return x if ref_leaf is None else x - ref_leaf.astype(jnp.float32)


def lossy_roundtrip(params, ref, bits: int):
    """What a lossy ring row decodes to for a STALE anchor.

    quantize(theta [- ref]) -> dequantize [+ ref], per leaf — the exact
    math :func:`mix_bucket_ring_lossy` applies at WRITE time, so the
    sequential reference (which keeps full-precision dict snapshots and
    decodes at READ time) lands on bit-identical base params.
    """
    r_leaves = [None] * len(jax.tree.leaves(params)) if ref is None \
        else jax.tree.leaves(ref)
    leaves, tdef = jax.tree.flatten(params)
    out = []
    for p, r in zip(leaves, r_leaves):
        codes, scale = CP.quantize(_lossy_delta(p, r), bits)
        dec = CP.dequantize(codes, scale)
        if r is not None:
            dec = dec + r.astype(jnp.float32)
        out.append(dec.astype(p.dtype))
    return jax.tree.unflatten(tdef, out)


def ring_gather_lossy(ring_q, ring_scales, fresh_buf, ref, base_slots,
                      fresh_idx, is_fresh):
    """Per-event base params out of a lossy ring (traced).

    Anchors inside the freshness window read full precision from the small
    rotating ``fresh_buf`` (row = agg % window); stale anchors dequantize
    their int ring row (+ ref for delta mode).  ``is_fresh`` is the (B,)
    0/1 per-event staleness flag — the SAME ``stale < window`` rule the
    sequential reference applies, so the engines agree event-for-event.
    """
    q_leaves = jax.tree.leaves(ring_q)
    s_leaves = jax.tree.leaves(ring_scales)
    f_leaves, tdef = jax.tree.flatten(fresh_buf)
    r_leaves = [None] * len(q_leaves) if ref is None else jax.tree.leaves(ref)
    out = []
    for qL, scL, fL, rL in zip(q_leaves, s_leaves, f_leaves, r_leaves):
        bshape = (-1,) + (1,) * (qL.ndim - 1)
        deq = (jnp.take(qL, base_slots, axis=0).astype(jnp.float32)
               * jnp.take(scL, base_slots).reshape(bshape))
        if rL is not None:
            deq = deq + rL.astype(jnp.float32)
        fp = jnp.take(fL, fresh_idx, axis=0).astype(jnp.float32)
        sel = is_fresh.reshape(bshape)
        out.append(jnp.where(sel > 0, fp, deq).astype(fL.dtype))
    return jax.tree.unflatten(tdef, out)


def mix_bucket_ring_lossy(global_params, ring_q, ring_scales, fresh_buf,
                          ref, write_slots, fresh_slots, stacked_params,
                          weights, bits: int):
    """:func:`mix_bucket_ring` for a lossy ring.

    Each post-mix global is written TWICE: quantized (int codes + one f32
    scale per leaf) into ring slot ``write_slots[i]``, and full-precision
    into rotating fresh-buffer row ``fresh_slots[i]`` (= agg % window) —
    readers within the freshness window take the fp row, everyone else
    pays the quantization (``ring_gather_lossy``).  Padding events write
    the scratch slot at weight 0, same as the exact ring.  Returns
    ``(global, ring_q, ring_scales, fresh_buf)``.
    """
    q_leaves, tdef = jax.tree.flatten(ring_q)
    s_leaves = jax.tree.leaves(ring_scales)
    r_leaves = [None] * len(q_leaves) if ref is None else [
        l.astype(jnp.float32) for l in jax.tree.leaves(ref)]

    def step(carry, x):
        g, qs, scs, fr = carry
        p, w, s, fs = x
        g = jax.tree.map(
            lambda gg, pp: ((1 - w) * gg.astype(jnp.float32)
                            + w * pp.astype(jnp.float32)).astype(gg.dtype),
            g, p)
        g_leaves = jax.tree.leaves(g)
        new_qs, new_scs = [], []
        for qL, scL, gL, rL in zip(qs, scs, g_leaves, r_leaves):
            codes, scale = CP.quantize(_lossy_delta(gL, rL), bits)
            new_qs.append(qL.at[s].set(codes))
            new_scs.append(scL.at[s].set(scale))
        fr = jax.tree.map(lambda f, gg: f.at[fs].set(gg.astype(f.dtype)),
                          fr, g)
        return (g, new_qs, new_scs, fr), None

    (g, qs, scs, fr), _ = jax.lax.scan(
        step, (global_params, q_leaves, s_leaves, fresh_buf),
        (stacked_params, weights, write_slots, fresh_slots))
    return g, jax.tree.unflatten(tdef, qs), jax.tree.unflatten(tdef, scs), fr


# ---------------------------------------------------------------------------
# snapshot ring buffer (bucketed async engine)
# ---------------------------------------------------------------------------


class RingAllocator:
    """Anchor-aware slot allocator for a fixed ring of snapshot rows.

    Host-side bookkeeping only (the rows themselves live in
    :class:`SnapshotRing`).  Each snapshot is identified by its aggregation
    id (the global mix counter at creation); clients "anchor" the id they
    last pulled from via retain/release refcounts.  Allocation reuses the
    oldest slot with refcount 0 — so a live anchor is NEVER evicted, the
    invariant the sequential engine's dict eviction maintains by scanning.
    The last slot is reserved scratch (padding writes land there).
    """

    def __init__(self, slots: int):
        assert slots >= 2, "need at least one data slot + scratch"
        self.slots = slots
        self._slot_agg = np.full(slots, -1, np.int64)
        self._refcnt = np.zeros(slots, np.int64)
        self._agg_slot: Dict[int, int] = {}
        self.anchor_misses = 0
        self.peak_live = 0

    @property
    def scratch(self) -> int:
        return self.slots - 1

    def seed(self, agg: int, slot: int = 0) -> None:
        """Install the initial snapshot id into a slot."""
        self._slot_agg[slot] = agg
        self._agg_slot[agg] = slot

    def slot_of(self, agg: int) -> int:
        s = self._agg_slot.get(agg)
        if s is None:
            # an anchored snapshot was evicted — the invariant the
            # allocator exists to uphold; surface loudly
            self.anchor_misses += 1
            raise KeyError(f"snapshot {agg} evicted while still anchored")
        return s

    def retain(self, agg: int) -> None:
        self._refcnt[self.slot_of(agg)] += 1
        self.peak_live = max(self.peak_live,
                             int(np.count_nonzero(self._refcnt)))

    def release(self, agg: int) -> None:
        s = self.slot_of(agg)
        assert self._refcnt[s] > 0, f"release of unanchored snapshot {agg}"
        self._refcnt[s] -= 1

    def alloc(self, agg: int) -> int:
        """Slot for a NEW snapshot ``agg``: the oldest unanchored data slot
        (never scratch, never a slot some client still reads through)."""
        free = np.where(self._refcnt[:-1] == 0)[0]
        if free.size == 0:
            raise RuntimeError(
                f"snapshot ring full: all {self.slots - 1} data slots are "
                "anchored (ring must be sized >= live anchors + 1)")
        s = int(free[np.argmin(self._slot_agg[free])])
        old = int(self._slot_agg[s])
        if old >= 0:
            del self._agg_slot[old]
        self._slot_agg[s] = agg
        self._agg_slot[agg] = s
        return s

    def live_slots(self) -> int:
        return int(np.count_nonzero(self._refcnt))


class SnapshotRing:
    """Device-side stacked snapshot store for the bucketed async engine.

    ``params`` is one pytree whose leaves carry a leading (slots,) axis —
    row r holds the global params as of some aggregation step.  Reads are a
    traced ``jnp.take`` over the bucket's anchor rows and writes happen
    inside the bucket program (:func:`mix_bucket_ring`), so per-event
    snapshotting never leaves the device.  Slot lifetime is managed by the
    host-side :class:`RingAllocator`; capacity is ``max(cap, anchors + 1)``
    data slots + 1 scratch, which by construction bounds the store the same
    way the sequential dict bounds itself (cap + live anchors).

    ``mode`` selects the anchor storage precision (the compression knob's
    ring leg): ``fp32`` keeps full-precision rows (today's exact store);
    ``quant``/``delta`` keep int-``bits`` codes + one f32 scale per
    (slot, leaf) — ``delta`` encodes vs a fixed full-precision reference
    (the global params at ring construction) — plus a small rotating
    full-precision buffer of the last ``fresh_window`` aggregation steps,
    so only anchors STALER than the window pay the quantization.
    """

    def __init__(self, params, cap: int, n_anchors: int,
                 mode: str = "fp32", bits: int = 8, fresh_window: int = 8):
        self.alloc = RingAllocator(max(cap, n_anchors + 1) + 1)
        self.mode, self.bits = mode, bits
        self.fresh_window = max(1, fresh_window)
        slots = self.alloc.slots
        if mode == "fp32":
            self.params = jax.tree.map(
                lambda x: jnp.zeros((slots,) + x.shape,
                                    x.dtype).at[0].set(x), params)
        elif mode in ("quant", "delta"):
            # jnp.array COPIES: astype(f32) on f32 leaves is a no-op alias
            # of the caller's params, and the bucket program donates its
            # globals — an aliased ref would be use-after-donate
            self.ref = jax.tree.map(lambda x: jnp.array(x, jnp.float32),
                                    params) if mode == "delta" else None
            r_leaves = [None] * len(jax.tree.leaves(params)) \
                if self.ref is None else jax.tree.leaves(self.ref)
            leaves, tdef = jax.tree.flatten(params)
            qs, scs = [], []
            for p, r in zip(leaves, r_leaves):
                codes, scale = CP.quantize(_lossy_delta(p, r), bits)
                qs.append(jnp.zeros((slots,) + p.shape,
                                    codes.dtype).at[0].set(codes))
                scs.append(jnp.ones((slots,), jnp.float32).at[0].set(scale))
            self.q = jax.tree.unflatten(tdef, qs)
            self.scales = jax.tree.unflatten(tdef, scs)
            # window rows (agg % window) + one scratch row padding events
            # write to (mirrors the int ring's scratch slot)
            self.fresh_buf = jax.tree.map(
                lambda x: jnp.zeros((self.fresh_window + 1,) + x.shape,
                                    x.dtype).at[0].set(x), params)
        else:
            raise ValueError(f"SnapshotRing: bad mode {mode!r}")
        self.alloc.seed(0, slot=0)

    @property
    def scratch(self) -> int:
        return self.alloc.scratch

    def read(self, agg: int, stale: Optional[int] = None):
        """Materialize snapshot ``agg`` (tests / inspection).  Lossy modes
        need the reader's ``stale`` to pick the fp fresh row vs the
        dequantized ring row — the same ``stale < fresh_window`` rule the
        engines trace."""
        s = self.alloc.slot_of(agg)
        if self.mode == "fp32":
            return jax.tree.map(lambda x: x[s], self.params)
        if stale is not None and stale < self.fresh_window:
            return jax.tree.map(lambda x: x[agg % self.fresh_window],
                                self.fresh_buf)
        r_leaves = [None] * len(jax.tree.leaves(self.q)) \
            if self.ref is None else jax.tree.leaves(self.ref)
        q_leaves, tdef = jax.tree.flatten(self.q)
        out = []
        for qL, scL, fL, rL in zip(q_leaves, jax.tree.leaves(self.scales),
                                   jax.tree.leaves(self.fresh_buf),
                                   r_leaves):
            dec = CP.dequantize(qL[s], scL[s])
            if rL is not None:
                dec = dec + rL.astype(jnp.float32)
            out.append(dec.astype(fL.dtype))
        return jax.tree.unflatten(tdef, out)

    def put(self, agg: int, params) -> int:
        """Store ``params`` as snapshot ``agg`` from the host loop — the
        sync delayed-gradient scheme's per-round write (the async engines
        write inside their bucket programs instead).  Allocation recycles
        the oldest unanchored slot, so with no retains a ``cap``-slot ring
        holds exactly the last ``cap`` puts.  fp32 mode only: the sync
        ring is small (delay+1 rows) and read exactly, so there is no
        lossy leg to mirror."""
        if self.mode != "fp32":
            raise ValueError(
                f"SnapshotRing.put requires mode='fp32', got {self.mode!r}")
        s = self.alloc.alloc(agg)
        self.params = jax.tree.map(lambda r, x: r.at[s].set(x),
                                   self.params, params)
        return s

    def nbytes(self) -> int:
        """Device bytes the anchor store holds — the memory axis the
        lossy modes exist to shrink (recorded by the bench)."""
        if self.mode == "fp32":
            return sum(x.nbytes for x in jax.tree.leaves(self.params))
        n = sum(x.nbytes for t in (self.q, self.scales, self.fresh_buf)
                for x in jax.tree.leaves(t))
        if self.ref is not None:
            n += sum(x.nbytes for x in jax.tree.leaves(self.ref))
        return n


@CT.contract(post=_finite_out)
def aggregate(cfg_mode: str, global_params, client_params,
              ratios=None, client_masks=None):
    if cfg_mode == "alpha_weighted":
        return aggregate_alpha(global_params, client_params, ratios)
    if cfg_mode == "masked_mean":
        return aggregate_masked_mean(global_params, client_params,
                                     client_masks, ratios)
    if cfg_mode == "uniform":
        return aggregate_uniform(global_params, client_params)
    raise ValueError(cfg_mode)
