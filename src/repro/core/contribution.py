"""Collaboration-contribution metric (paper Eq. 1).

U^{ij}(S_k) = theta^{ij}(S_k) - theta^{ij}(S_{k-1}) per neuron — we reduce the
per-neuron weight-delta vector with an L1 norm over its fan-in/fan-out entries
(DESIGN.md §7.5: "changing values" reads as magnitude).

The reduction is driven entirely by LOGICAL AXES: for unit key ``mlp`` every
parameter that carries an ``mlp`` axis contributes |delta| summed over all its
other dims, aligned to the (layers, units) mask layout.  The same machinery
computes per-unit scores for any family (heads, experts, ssm_heads, conv
filters) without model-specific code.

Config switch ``contribution``:
  * ``delta``    — paper-faithful Eq. 1 (needs the previous cycle's params);
  * ``grad_ema`` — EMA of per-unit |grad| (refs [18][20]); O(units) state,
    used in the datacenter path where keeping a second copy of 236B params
    per client is wasteful (DESIGN.md §7.4).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.module import tree_paths

#: mask-schema key -> the logical axis that identifies the unit dim
UNIT_AXES = {
    "mlp": "mlp",
    "heads": "heads",
    "enc_heads": "heads",
    "cross_heads": "heads",
    "enc_mlp": "mlp",
    "experts": "experts",
    "ssm_heads": "ssm_heads",
    "slstm_heads": "ssm_heads",
}


def _reduce_to_units(arr: jax.Array, axes: tuple, unit_axis: str,
                     layered: bool) -> jax.Array:
    """|arr| summed over every dim except (layers?, unit_axis)."""
    keep = []
    if layered and axes and axes[0] == "layers":
        keep.append(0)
    try:
        u = axes.index(unit_axis)
    except ValueError:
        return None
    keep.append(u)
    red = tuple(i for i in range(arr.ndim) if i not in keep)
    out = jnp.sum(jnp.abs(arr.astype(jnp.float32)), axis=red)
    if not (layered and axes and axes[0] == "layers"):
        out = out[None]                                   # (1, units)
    return out


def unit_scores(delta_tree, axes_tree, schema: Dict[str, tuple],
                key_prefixes: Dict[str, str] | None = None) -> Dict[str, jax.Array]:
    """Per-unit L1 scores of a param-delta (or grad) tree.

    Returns {schema_key: (layers, units) float32}.  ``key_prefixes``
    optionally restricts a schema key to param paths with a prefix — needed
    when the same logical axis appears in several stacks (e.g. encoder vs
    decoder heads).
    """
    params = dict(tree_paths(delta_tree))
    axes = dict(tree_paths(axes_tree, is_leaf=lambda x: isinstance(x, tuple)))
    out = {}
    for key, shape in schema.items():
        # schema keys may carry a path-component prefix: "b3:ssm_heads"
        # restricts to params whose path contains the component "b3"
        # (unrolled per-layer stacks, e.g. xLSTM blocks).
        if ":" in key:
            prefix, axis_key = key.split(":", 1)
        else:
            prefix, axis_key = (key_prefixes or {}).get(key), key
        unit_axis = UNIT_AXES.get(axis_key, "filters")
        acc = jnp.zeros(shape, jnp.float32)
        for path, arr in params.items():
            ax = axes.get(path)
            if ax is None or unit_axis not in ax:
                continue
            if prefix is not None and f"/{prefix}/" not in f"/{path}/":
                continue
            if axis_key.startswith("enc_") and "enc_" not in path:
                continue
            if not axis_key.startswith("enc_") and prefix is None and \
                    axis_key in ("heads", "mlp") and path.startswith("enc_"):
                continue
            if axis_key == "cross_heads" and "/cross/" not in f"/{path}/":
                continue
            if axis_key == "heads" and "cross" in path:
                continue
            r = _reduce_to_units(arr, ax, unit_axis, layered=True)
            if r is None or r.shape != tuple(shape):
                continue
            acc = acc + r
        out[key] = acc
    return out


def cnn_unit_scores(delta_tree, schema: Dict[str, tuple]) -> Dict[str, jax.Array]:
    """CNN variant: schema keys ARE param-name prefixes (conv0, fc1, ...)."""
    params = dict(tree_paths(delta_tree))
    out = {}
    for key, shape in schema.items():
        w = params.get(f"{key}_w")
        b = params.get(f"{key}_b")
        acc = jnp.zeros(shape[-1], jnp.float32)
        if w is not None:
            red = tuple(range(w.ndim - 1))
            acc = acc + jnp.sum(jnp.abs(w.astype(jnp.float32)), axis=red)
        if b is not None:
            acc = acc + jnp.abs(b.astype(jnp.float32))
        out[key] = acc[None]                              # (1, units)
    return out


def delta(params_new, params_old):
    return jax.tree.map(lambda a, b: a.astype(jnp.float32) -
                        b.astype(jnp.float32), params_new, params_old)


def ema_update(scores_prev: Dict[str, jax.Array],
               scores_new: Dict[str, jax.Array], decay: float):
    return {k: decay * scores_prev[k] + (1 - decay) * scores_new[k]
            for k in scores_new}
