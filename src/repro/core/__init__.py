"""Helios core: the paper's contribution as a composable JAX module."""
from repro.core import (aggregation, contribution, identification, masking,
                        selection, soft_train, theory, volume)

__all__ = ["aggregation", "contribution", "identification", "masking",
           "selection", "soft_train", "theory", "volume"]
