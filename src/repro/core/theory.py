"""Proposition-2 utilities: gradient-variance bound of soft-training.

Consumed by the scheme-gauntlet bench (benchmarks/run.py), which prices
every soft-training scheme's gradient variance at its settled straggler
volumes, and by the hypothesis property tests.

Soft-training's sampled gradient is the importance-sampling estimator
ST(g)_i = D_i g_i / p_i (Eq. 5); its second moment is sum_i g_i^2 / p_i
(Eq. 6).  Keeping the top-v coordinates with p=1 and sampling the tail with
p_i proportional to |g_i| (Wangni et al. [19]) satisfies
sum g_i^2/p_i <= (1+eps) sum g_i^2 with expected sparsity <= (1+rho) v
(Eq. 9).  These functions are exercised by the hypothesis property tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def st_estimate(g: jax.Array, p: jax.Array, key: jax.Array) -> jax.Array:
    """One draw of the unbiased estimator ST(g)_i = D_i g_i / p_i."""
    d = (jax.random.uniform(key, g.shape) < p).astype(g.dtype)
    return d * g / jnp.maximum(p, 1e-12)


def st_second_moment(g: jax.Array, p: jax.Array) -> jax.Array:
    """E||ST(g)||^2 = sum_i g_i^2 / p_i (Eq. 6)."""
    return jnp.sum(jnp.square(g) / jnp.maximum(p, 1e-12))


def variance_inflation(g: jax.Array, p: jax.Array) -> jax.Array:
    """epsilon such that E||ST(g)||^2 = (1+eps) ||g||^2."""
    base = jnp.sum(jnp.square(g))
    return st_second_moment(g, p) / jnp.maximum(base, 1e-30) - 1.0


def wangni_probabilities(g: jax.Array, v: int) -> jax.Array:
    """Optimal selection probabilities: top-v kept (p=1), tail p_i ~ |g_i|.

    The tail scale lambda is chosen so the expected number of sampled tail
    coordinates is ~rho*v with rho set by the variance constraint; here we
    normalize the tail to an expected v/2 extra samples (a practical choice;
    the property tests only rely on p_i in (0, 1] and the Eq. 9 bound).
    """
    n = g.shape[0]
    absg = jnp.abs(g)
    order = jnp.argsort(-absg)
    ranks = jnp.argsort(order)
    in_top = ranks < v
    tail = jnp.where(in_top, 0.0, absg)
    tail_sum = jnp.maximum(jnp.sum(tail), 1e-30)
    budget = v / 2
    p_tail = jnp.clip(tail / tail_sum * budget, 1e-6, 1.0)
    return jnp.where(in_top, 1.0, p_tail)


def expected_sparsity(p: jax.Array) -> jax.Array:
    """E||ST(g)||_0 = sum_i p_i (Eq. 9 LHS)."""
    return jnp.sum(p)


def check_convergence_condition(g: jax.Array, v: int, rho: float):
    """Eq. 9: with top-v at p=1, E||ST(g)||_0 <= (1+rho) v for the Wangni
    tail distribution with expected tail mass rho*v."""
    absg = jnp.abs(g)
    order = jnp.argsort(-absg)
    ranks = jnp.argsort(order)
    in_top = ranks < v
    tail = jnp.where(in_top, 0.0, absg)
    tail_sum = jnp.maximum(jnp.sum(tail), 1e-30)
    p_tail = jnp.clip(tail / tail_sum * (rho * v), 0.0, 1.0)
    p = jnp.where(in_top, 1.0, p_tail)
    return expected_sparsity(p), (1 + rho) * v
