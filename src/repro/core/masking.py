"""Expand per-unit Helios masks into parameter-space masks.

Used for (a) gradient/update masking in the train step, (b) per-coordinate
masked-mean aggregation (the beyond-paper aggregation option), and (c) the
theory utilities.  A parameter whose logical axes contain several maskable
unit axes (e.g. MoE ``wi``: experts x mlp) gets the OUTER PRODUCT of the unit
masks.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.contribution import UNIT_AXES
from repro.models.module import tree_paths


def _match(key: str, path: str, axes: tuple) -> str | None:
    """Return the unit axis name if schema ``key`` applies to this param."""
    if ":" in key:
        prefix, axis_key = key.split(":", 1)
        if f"/{prefix}/" not in f"/{path}/":
            return None
    else:
        axis_key = key
    unit_axis = UNIT_AXES.get(axis_key, "filters")
    if unit_axis not in axes:
        return None
    if axis_key.startswith("enc_") and "enc_" not in path:
        return None
    if not axis_key.startswith("enc_") and axis_key in ("heads", "mlp") and \
            path.startswith("enc_"):
        return None
    if axis_key == "cross_heads" and "/cross/" not in f"/{path}/":
        return None
    if axis_key == "heads" and "cross" in path:
        return None
    return unit_axis


def expand_masks(axes_tree, unit_masks: Dict[str, jax.Array], params_tree):
    """Build a params-shaped 0/1 mask tree from unit masks.

    Parameters with no maskable axis get all-ones (they always train:
    norms, embeddings, routers, biases of unmasked layers...).
    """
    axes = dict(tree_paths(axes_tree, is_leaf=lambda x: isinstance(x, tuple)))
    flat_params = tree_paths(params_tree)
    out = {}
    for path, arr in flat_params:
        ax = axes.get(path)
        m = jnp.ones(arr.shape, jnp.float32)
        if ax is not None:
            layered = bool(ax) and ax[0] == "layers"
            for key, um in unit_masks.items():
                unit_axis = _match(key, path, ax)
                if unit_axis is None:
                    continue
                dim = ax.index(unit_axis)
                n_layers, n_units = um.shape
                if arr.shape[dim] != n_units:
                    continue
                if layered and arr.shape[0] != n_layers:
                    continue
                if not layered and n_layers != 1:
                    continue
                shape = [1] * arr.ndim
                shape[dim] = n_units
                if layered:
                    shape[0] = n_layers
                    m = m * um.reshape(shape)
                else:
                    m = m * um[0].reshape(shape)
        out[path] = m
    # rebuild nested structure
    return _unflatten(out)


def cnn_expand_masks(unit_masks: Dict[str, jax.Array], params_tree):
    """CNN variant: keys are param-name prefixes; mask the OUTPUT channel."""
    out = {}
    for path, arr in tree_paths(params_tree):
        m = jnp.ones(arr.shape, jnp.float32)
        for key, um in unit_masks.items():
            v = um[0] if um.ndim == 2 else um
            if path == f"{key}_w" and arr.shape[-1] == v.shape[0]:
                m = m * v.reshape((1,) * (arr.ndim - 1) + (-1,))
            elif path == f"{key}_b" and arr.shape[0] == v.shape[0]:
                m = m * v
        out[path] = m
    return _unflatten(out)


def _unflatten(flat: Dict[str, jax.Array]):
    tree: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def apply_mask_tree(tree, mask_tree):
    return jax.tree.map(lambda t, m: t * m.astype(t.dtype), tree, mask_tree)


def selected_fraction(unit_masks: Dict[str, jax.Array]) -> jax.Array:
    """r_n of Eq. 10: fraction of maskable units selected on this client."""
    tot = sum(m.size for m in unit_masks.values())
    sel = sum(jnp.sum(m) for m in unit_masks.values())
    return sel / max(tot, 1)


def cnn_expand_masks_batch(unit_masks: Dict[str, jax.Array], params_tree):
    """``cnn_expand_masks`` over a stacked cohort.

    unit_masks leaves carry a leading client axis (C, L, n); params_tree is
    the UNstacked global template.  Returns a params-shaped mask tree whose
    leaves are (C,) + param.shape, ready for the stacked masked-mean
    aggregation.
    """
    return jax.vmap(lambda um: cnn_expand_masks(um, params_tree))(unit_masks)


def expand_masks_batch(axes_tree, unit_masks: Dict[str, jax.Array],
                       params_tree):
    """``expand_masks`` over a stacked cohort (generic, axis-driven).

    The logical-axes counterpart of :func:`cnn_expand_masks_batch`:
    unit_masks leaves carry a leading client axis (C, L, n); params_tree is
    the UNstacked global template.  Returns leaves shaped (C,) + param.shape
    for the stacked masked-mean aggregation of any maskable family.
    """
    return jax.vmap(lambda um: expand_masks(axes_tree, um, params_tree))(
        unit_masks)
