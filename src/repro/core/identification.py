"""Straggler identification (Section IV.B).

* Time-based approximation (BLACK BOX): run a lightweight test bench (a few
  training iterations) per device, rank by observed time, take the top-k as
  potential stragglers.
* Resource-based profiling (WHITE BOX): the paper's cost model
  ``Te = W/C_cpu + M/V_mc + M/B_n`` fed with device resources.  On TPU the
  white-box profile is the compiled dry-run's cost_analysis (strictly more
  accurate — DESIGN.md §2); this module accepts either source for W and M.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Hardware resources of one collaboration device.

    Units: compute GFLOP/s, memory MB, mem bandwidth MB/s, net MB/s.
    ``speed_factor`` scales simulated step time (heterogeneity simulator).
    """

    name: str
    compute_gflops: float
    memory_mb: float
    mem_bandwidth: float
    net_bandwidth: float
    speed_factor: float = 1.0


def time_cost_model(workload_gflop: float, memory_mb: float,
                    dev: DeviceProfile) -> float:
    """Te = W/C_cpu + M/V_mc + M/B_n (paper Section IV.B)."""
    return (workload_gflop / dev.compute_gflops
            + memory_mb / dev.mem_bandwidth
            + memory_mb / dev.net_bandwidth)


def identify_resource_based(workload_gflop: float, memory_mb: float,
                            devices: Sequence[DeviceProfile],
                            num_stragglers: Optional[int] = None,
                            slack: float = 1.5):
    """White-box: model Te per device; stragglers are the top-k slowest (or
    everything slower than slack x median when k is not given).

    Returns (times, straggler_indices) with times in the T-index order
    convention (T_1 = longest).
    """
    times = [time_cost_model(workload_gflop, memory_mb, d) for d in devices]
    order = sorted(range(len(times)), key=lambda i: -times[i])
    if num_stragglers is None:
        # slack x FASTEST device: robust even when most devices straggle
        fastest = min(times)
        stragglers = [i for i in order if times[i] > slack * fastest]
    else:
        stragglers = order[:num_stragglers]
    return times, stragglers


def identify_time_based(bench_fn: Callable[[int], None],
                        num_devices: int,
                        probe_iters: int = 3,
                        num_stragglers: Optional[int] = None,
                        timer: Callable[[], float] = time.perf_counter,
                        simulated_times: Optional[Sequence[float]] = None):
    """Black-box: time a probe bench per device and rank.

    ``bench_fn(device_index)`` runs one probe iteration on that device.  In
    the simulator, ``simulated_times`` short-circuits wall-clock measurement.
    """
    if simulated_times is not None:
        times = list(simulated_times)
    else:
        times = []
        for dev in range(num_devices):
            t0 = timer()
            for _ in range(probe_iters):
                bench_fn(dev)
            times.append((timer() - t0) / probe_iters)
    order = sorted(range(num_devices), key=lambda i: -times[i])
    if num_stragglers is None:
        fastest = min(times)
        stragglers = [i for i in order if times[i] > 1.5 * fastest]
    else:
        stragglers = order[:num_stragglers]
    return times, stragglers
