"""Soft-training cycle state machine (Section V, Fig. 4).

One Helios client's per-cycle flow:

  begin_cycle:  forced = {C_s >= threshold}            (Section VI.A)
                masks  = TopK(U) ∪ Rand ∪ forced        (Eq. 2)
  ... local training with masked forward/grads ...
  end_cycle:    U      = per-unit |theta_k - theta_{k-1}|   (Eq. 1)
                C_s    = 0 where trained else +1

The state is a plain dict pytree (jit-able, checkpointable).

All transforms are vmap-safe (no Python branching on traced values; the PRNG
key lives inside the state so per-client splitting vectorizes), so a whole
cohort of clients can be stacked along a leading axis (``stack_states``) and
``begin_cycle``/``end_cycle`` vmapped inside one jitted round program
(federated.runtime.BatchedFLRun).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts as CT
from repro.configs.base import HeliosConfig
from repro.core import contribution as C
from repro.core import selection as S


def full_masks(schema: Dict[str, tuple]) -> Dict[str, jax.Array]:
    """All-ones unit masks — the 'train the whole model' selection shared
    by capable clients, the syn/asyn/afo baselines, and padding slots."""
    return {k: jnp.ones(s, jnp.float32) for k, s in schema.items()}


def init_state(schema: Dict[str, tuple], volume: float = 1.0,
               seed: int = 0) -> dict:
    return {
        "masks": full_masks(schema),
        "scores": S.init_scores(schema),
        "skip_counts": S.init_skip_counts(schema),
        "volume": jnp.asarray(volume, jnp.float32),
        "rng": jax.random.PRNGKey(seed),
        "cycle": jnp.asarray(0, jnp.int32),
    }


def _begin_cycle_post(out: dict, state: dict, hcfg: HeliosConfig) -> None:
    """begin_cycle contract: Eq. 2 masks are 0/1, block-constant at
    ``mask_block`` granularity with ~P·n units kept, and the PRNG key
    advanced (no reuse across cycles).  Value checks bail under tracing
    (the batched/sharded engines run begin_cycle vmapped in jit)."""
    if not hcfg.enabled:
        return
    CT.check_mask_invariants(out["masks"], out["volume"],
                             hcfg.mask_block, tag="begin_cycle")
    if not CT.has_tracers(out["rng"], state["rng"]):
        with CT.expected_transfer("contracts.begin_cycle.rng"):
            if bool(jnp.all(out["rng"] == state["rng"])):
                raise CT.ContractError(
                    "begin_cycle: rng key not advanced — the next cycle "
                    "would redraw identical masks")


@CT.contract(post=_begin_cycle_post)
def begin_cycle(state: dict, hcfg: HeliosConfig) -> dict:
    """Select this cycle's masks from scores + rotation state.

    With ``hcfg.mask_block`` set, Eq. 2 selection runs at BLOCK granularity
    (block-pooled scores, block-constant masks, ~P·n units kept) — the
    single seam all engines share, so seq/batched/sharded/async cohorts
    stay mask-identical and the Pallas kernels skip dead blocks
    structurally without losing the compressed volume.
    """
    if not hcfg.enabled:
        return state
    rng, sub = jax.random.split(state["rng"])
    thresh = S.rotation_threshold(state["volume"],
                                  hcfg.rotation_threshold_auto,
                                  hcfg.rotation_threshold)
    forced = S.forced_units(state["skip_counts"], thresh)
    masks = S.select_masks(state["scores"], forced, state["volume"],
                           hcfg.p_s, sub, block=hcfg.mask_block)
    return {**state, "masks": masks, "rng": rng}


def end_cycle(state: dict, scores_new: Dict[str, jax.Array],
              hcfg: HeliosConfig) -> dict:
    """Fold in this cycle's contribution scores + update C_s counters."""
    if hcfg.contribution == "grad_ema":
        scores = C.ema_update(state["scores"], scores_new,
                              hcfg.contribution_ema)
    else:
        scores = scores_new                                # Eq. 1 delta
    return {
        **state,
        "scores": scores,
        "skip_counts": S.update_skip_counts(state["skip_counts"],
                                            state["masks"]),
        "cycle": state["cycle"] + 1,
    }


def cycle_scores(params_new, params_old, axes_tree,
                 schema) -> Dict[str, jax.Array]:
    """Eq. 1 scores from a cycle's parameter delta (axis-driven).

    Family dispatch (axis-driven vs CNN prefix-keyed reduction) lives in
    federated.adapter.FamilyAdapter.cycle_scores — no family strings here.
    """
    return C.unit_scores(C.delta(params_new, params_old), axes_tree, schema)


def grad_scores(grads, axes_tree, schema):
    """grad_ema variant: per-unit |grad| of one step (O(units) state)."""
    return C.unit_scores(grads, axes_tree, schema)


def set_volume(state: dict, volume: float) -> dict:
    return {**state, "volume": jnp.asarray(volume, jnp.float32)}


# ---------------------------------------------------------------------------
# batched (stacked-client) state
# ---------------------------------------------------------------------------


def stack_states(states: Sequence[dict]) -> dict:
    """Stack per-client states into one pytree with a leading client axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def unstack_states(stacked: dict, n: int) -> List[dict]:
    """Inverse of ``stack_states``: n per-client state dicts."""
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(n)]


def set_volumes(stacked: dict, volumes: Sequence[float]) -> dict:
    """Write the (C,) volume leaf of a stacked state."""
    return {**stacked, "volume": jnp.asarray(volumes, jnp.float32)}


# ---------------------------------------------------------------------------
# persistent-population state (partial participation)
# ---------------------------------------------------------------------------


def init_population(schema: Dict[str, tuple], volumes: Sequence[float],
                    seeds: Sequence[int]) -> dict:
    """Stacked state for a whole population, built WITHOUT materializing N
    per-client dicts.

    Row i is bit-identical to ``init_state(schema, volume=volumes[i],
    seed=seeds[i])`` (the PRNG keys are vmapped ``PRNGKey`` calls), so a
    population engine seeds exactly like the sequential reference.
    """
    n = len(list(seeds))
    return {
        "masks": {k: jnp.ones((n,) + tuple(s), jnp.float32)
                  for k, s in schema.items()},
        "scores": {k: jnp.zeros((n,) + tuple(s), jnp.float32)
                   for k, s in schema.items()},
        "skip_counts": {k: jnp.zeros((n,) + tuple(s), jnp.int32)
                        for k, s in schema.items()},
        "volume": jnp.asarray(list(volumes), jnp.float32),
        "rng": jax.vmap(jax.random.PRNGKey)(
            jnp.asarray(list(seeds), jnp.int64
                        if jax.config.jax_enable_x64 else jnp.int32)),
        "cycle": jnp.zeros((n,), jnp.int32),
    }


def host_states(stacked: dict) -> dict:
    """Population state with HOST (numpy) leaves.

    The sharded engine keeps the N-client state host-resident: per-round
    gathers copy just the cohort's rows to device, scatters write them back
    IN PLACE (no N-sized reallocation per round), and — because host arrays
    are uncommitted jit inputs — every round presents the identical input
    sharding signature, so the round program never recompiles.
    """
    # np.array (not asarray): device arrays view as READ-ONLY numpy; the
    # population rows must stay writable for in-place scatters
    return jax.tree.map(np.array, stacked)


def gather_states_host(pop: dict, idx) -> dict:
    """Cohort rows of a host population state (fancy indexing => copies,
    so later in-place scatters can't corrupt the gathered cohort)."""
    idx = np.asarray(idx)
    return jax.tree.map(lambda x: x[idx], pop)


def scatter_states_host(pop: dict, idx, sub: dict) -> None:
    """In-place inverse of ``gather_states_host`` (``idx`` duplicate-free;
    device leaves in ``sub`` are pulled to host)."""
    idx = np.asarray(idx)

    def write(x, s):
        x[idx] = np.asarray(s)

    # an INTENDED device->host pull: the population state is host-resident
    # by design (shape-stable jit inputs), so the transfer guard must not
    # flag the per-round write-back
    with CT.expected_transfer("soft_train.scatter_states_host"):
        jax.tree.map(write, pop, sub)
