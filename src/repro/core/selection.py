"""Neuron selection (paper Eq. 2) + rotation regulation (Section VI.A).

Per layer, per unit type, with volume fraction P and contribution scores U:

  selected = TopK(U) ∪ Rand(rest) ∪ Forced(C_s over threshold)
  |TopK| = P_s * P * n      (primary convergence guarantee, Prop. 2)
  |Rand| = (1-P_s) * P * n  (rotation -> model integrity)

Counts are TRACED (thresholding a sorted array) so the adaptive volume
controller can change P without recompiling.  Forced units (skipped for
C_s > threshold cycles, Section VI.A) preempt the random draw — "pull the
long-term skipped neurons back to training timely".
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def _row_select(u: jax.Array, forced: jax.Array, k_total: jax.Array,
                k_top: jax.Array, key: jax.Array) -> jax.Array:
    """One layer row.  u: (n,) scores; forced: (n,) bool; returns (n,) 0/1."""
    n = u.shape[0]
    noise = jax.random.uniform(key, (n,), minval=0.0, maxval=1e-6)
    u = u + noise                                         # random tie-break

    # top-k by threshold on the sorted scores (k is traced)
    su = jnp.sort(u)
    idx_top = jnp.clip(n - k_top, 0, n - 1)
    thresh = su[idx_top]
    is_top = jnp.where(k_top > 0, u >= thresh, False)

    # priority: forced >> top >> random
    rand = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    prio = forced.astype(jnp.float32) * 4.0 + is_top.astype(jnp.float32) * 2.0 + rand
    sp = jnp.sort(prio)
    idx_tot = jnp.clip(n - k_total, 0, n - 1)
    pthresh = sp[idx_tot]
    mask = (prio >= pthresh).astype(jnp.float32)
    return mask


def select_masks(scores: Dict[str, jax.Array],
                 forced: Dict[str, jax.Array],
                 volume: jax.Array,
                 p_s: float,
                 key: jax.Array) -> Dict[str, jax.Array]:
    """Eq. 2 across all unit types.  scores/forced: {key: (L, n)}.

    ``volume`` is the client's P (scalar in (0, 1], traced).  Returns masks
    {key: (L, n) float 0/1} with ~P*n ones per row.  Traced counts plus the
    explicit key argument make this directly vmap-able over a stacked client
    cohort (federated.runtime.BatchedFLRun vmaps the whole cycle).
    """
    out = {}
    for i, (k, u) in enumerate(sorted(scores.items())):
        L, n = u.shape
        k_total = jnp.clip(jnp.round(volume * n).astype(jnp.int32), 1, n)
        k_top = jnp.round(p_s * k_total).astype(jnp.int32)
        rows = jax.vmap(_row_select, in_axes=(0, 0, None, None, 0))(
            u, forced.get(k, jnp.zeros_like(u, bool)), k_total, k_top,
            jax.random.split(jax.random.fold_in(key, i), L))
        out[k] = rows
    return out


def update_skip_counts(skip_counts: Dict[str, jax.Array],
                       masks: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """C_s: 0 when the unit joined this cycle, else +1."""
    return {k: jnp.where(masks[k] > 0, 0, skip_counts[k] + 1)
            for k in skip_counts}


def rotation_threshold(volume: jax.Array, auto: bool = True,
                       fixed: int = 4) -> jax.Array:
    """Section VI.A: threshold = 1 + m / sum(p_i n_i) = 1 + 1/P."""
    if not auto:
        return jnp.asarray(fixed, jnp.float32)
    return 1.0 + 1.0 / jnp.maximum(volume, 1e-3)


def forced_units(skip_counts: Dict[str, jax.Array],
                 threshold: jax.Array) -> Dict[str, jax.Array]:
    return {k: v.astype(jnp.float32) >= threshold for k, v in
            skip_counts.items()}


def init_skip_counts(schema: Dict[str, Tuple[int, int]]):
    return {k: jnp.zeros(s, jnp.int32) for k, s in schema.items()}


def init_scores(schema: Dict[str, Tuple[int, int]]):
    return {k: jnp.zeros(s, jnp.float32) for k, s in schema.items()}
