"""Neuron selection (paper Eq. 2) + rotation regulation (Section VI.A).

Per layer, per unit type, with volume fraction P and contribution scores U:

  selected = TopK(U) ∪ Rand(rest) ∪ Forced(C_s over threshold)
  |TopK| = P_s * P * n      (primary convergence guarantee, Prop. 2)
  |Rand| = (1-P_s) * P * n  (rotation -> model integrity)

Counts are TRACED (thresholding a sorted array) so the adaptive volume
controller can change P without recompiling.  Forced units (skipped for
C_s > threshold cycles, Section VI.A) preempt the random draw — "pull the
long-term skipped neurons back to training timely".
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import contracts as CT


def _select_masks_pre(scores, forced, volume, p_s, key, block=0):
    """Eq. 2 precondition: (L, n)-shaped score/forced rows, a scalar
    volume, and p_s in [0, 1].  Shape-level only, so it runs under
    jit/vmap tracing too (shapes are always concrete)."""
    for k, u in scores.items():
        if getattr(u, "ndim", None) != 2:
            raise CT.ContractError(
                f"select_masks: scores[{k!r}] must be (L, n), got "
                f"shape {getattr(u, 'shape', None)}")
        f = forced.get(k)
        if f is not None and f.shape != u.shape:
            raise CT.ContractError(
                f"select_masks: forced[{k!r}] shape {f.shape} != "
                f"scores shape {u.shape}")
    if getattr(volume, "shape", ()) not in ((), (1,)):
        raise CT.ContractError(
            f"select_masks: volume must be scalar, got shape "
            f"{volume.shape}")
    if not 0.0 <= float(p_s) <= 1.0:
        raise CT.ContractError(f"select_masks: p_s={p_s} outside [0, 1]")


def _row_select(u: jax.Array, forced: jax.Array, k_total: jax.Array,
                k_top: jax.Array, key: jax.Array) -> jax.Array:
    """One layer row.  u: (n,) scores; forced: (n,) bool; returns (n,) 0/1."""
    n = u.shape[0]
    noise = jax.random.uniform(key, (n,), minval=0.0, maxval=1e-6)
    u = u + noise                                         # random tie-break

    # top-k by threshold on the sorted scores (k is traced)
    su = jnp.sort(u)
    idx_top = jnp.clip(n - k_top, 0, n - 1)
    thresh = su[idx_top]
    is_top = jnp.where(k_top > 0, u >= thresh, False)

    # priority: forced >> top >> random
    rand = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    prio = forced.astype(jnp.float32) * 4.0 + is_top.astype(jnp.float32) * 2.0 + rand
    sp = jnp.sort(prio)
    idx_tot = jnp.clip(n - k_total, 0, n - 1)
    pthresh = sp[idx_tot]
    mask = (prio >= pthresh).astype(jnp.float32)
    return mask


def _pool_blocks(u: jax.Array, block: int, reduce: str) -> jax.Array:
    """(L, n) unit values -> (L, ceil(n/block)) per-block values.

    ``mean`` averages over the REAL entries of the ragged tail block (the
    zero padding never dilutes a block's score); ``max`` is any-of.
    """
    L, n = u.shape
    nb = -(-n // block)
    up = jnp.pad(u, ((0, 0), (0, nb * block - n)))
    grouped = up.reshape(L, nb, block)
    if reduce == "mean":
        cnt = jnp.minimum(block, n - jnp.arange(nb) * block)
        return grouped.sum(-1) / cnt[None, :]
    return grouped.max(-1)


def _expand_blocks(bm: jax.Array, block: int, n: int) -> jax.Array:
    """Inverse of :func:`_pool_blocks` for 0/1 masks: block-constant (L, n)."""
    return jnp.repeat(bm, block, axis=-1)[..., :n]


@CT.contract(pre=_select_masks_pre)
def select_masks(scores: Dict[str, jax.Array],
                 forced: Dict[str, jax.Array],
                 volume: jax.Array,
                 p_s: float,
                 key: jax.Array,
                 block: int = 0) -> Dict[str, jax.Array]:
    """Eq. 2 across all unit types.  scores/forced: {key: (L, n)}.

    ``volume`` is the client's P (scalar in (0, 1], traced).  Returns masks
    {key: (L, n) float 0/1} with ~P*n ones per row.  Traced counts plus the
    explicit key argument make this directly vmap-able over a stacked client
    cohort (federated.runtime.BatchedFLRun vmaps the whole cycle).

    ``p_s`` interpolates the draw: 0.0 is pure random rotation (the Caldas
    baseline), 1.0 is pure score top-k (k_top == k_total, no random tail) —
    which is exactly FLuID's invariant-dropout selection, so the ``fluid``
    scheme reuses this function unchanged (federated.schemes._fluid_hcfg).

    ``block`` > 0 runs Eq. 2 at BLOCK granularity (beyond-paper, for the
    Pallas kernels): unit scores are mean-pooled per block, forced flags
    any-pooled, the top-k/random/forced draw picks ~P·(n/block) blocks, and
    the mask expands block-constant.  Rounding a unit-scattered selection
    UP instead (block_align_mask) degenerates to the full model — a block
    survives only with probability (1-P)^block — so selecting blocks is the
    version that keeps the compressed volume at P while staying
    structurally skippable.

    Pooling applies ONLY to unit types with n >= 4·block.  Block selection
    quantizes a layer's volume to the 1/nb grid with a floor of one block,
    so few-block layers would silently train far above P (one-of-two
    blocks = 50% minimum); requiring nb >= 4 bounds the grid at 1/4 —
    conv channels, attention heads, and tiny fc layers keep unit-granular
    Eq. 2 and their exact share of P, at the cost of no structural skip
    there (on TPU the layers that matter are 16+ blocks wide and their
    grid is fine).
    """
    if block:
        pooled = {k for k, u in scores.items()
                  if u.shape[-1] >= 4 * block}
        if not pooled:
            # nothing qualifies for pooling: fall straight through to the
            # unit-granular path on the ORIGINAL key, so mask_block > 0 on
            # a small model stays seed-compatible with mask_block = 0
            return select_masks(scores, forced, volume, p_s, key)
        bscores = {k: _pool_blocks(scores[k], block, "mean")
                   for k in pooled}
        bforced = {k: _pool_blocks(forced[k].astype(jnp.float32), block,
                                   "max").astype(bool)
                   for k in pooled if k in forced}
        # distinct subkeys per group: two unit types of equal size in
        # different groups must not share a selection stream
        bmasks = select_masks(bscores, bforced, volume, p_s,
                              jax.random.fold_in(key, 0xB10C))
        unit = select_masks({k: u for k, u in scores.items()
                             if k not in pooled},
                            {k: f for k, f in forced.items()
                             if k not in pooled}, volume, p_s,
                            jax.random.fold_in(key, 0x0A11))
        return {k: _expand_blocks(bmasks[k], block, scores[k].shape[-1])
                if k in pooled else unit[k] for k in scores}
    out = {}
    for i, (k, u) in enumerate(sorted(scores.items())):
        L, n = u.shape
        k_total = jnp.clip(jnp.round(volume * n).astype(jnp.int32), 1, n)
        k_top = jnp.round(p_s * k_total).astype(jnp.int32)
        rows = jax.vmap(_row_select, in_axes=(0, 0, None, None, 0))(
            u, forced.get(k, jnp.zeros_like(u, bool)), k_total, k_top,
            jax.random.split(jax.random.fold_in(key, i), L))
        out[k] = rows
    return out


def update_skip_counts(skip_counts: Dict[str, jax.Array],
                       masks: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """C_s: 0 when the unit joined this cycle, else +1."""
    return {k: jnp.where(masks[k] > 0, 0, skip_counts[k] + 1)
            for k in skip_counts}


def rotation_threshold(volume: jax.Array, auto: bool = True,
                       fixed: int = 4) -> jax.Array:
    """Section VI.A: threshold = 1 + m / sum(p_i n_i) = 1 + 1/P."""
    if not auto:
        return jnp.asarray(fixed, jnp.float32)
    return 1.0 + 1.0 / jnp.maximum(volume, 1e-3)


def forced_units(skip_counts: Dict[str, jax.Array],
                 threshold: jax.Array) -> Dict[str, jax.Array]:
    return {k: v.astype(jnp.float32) >= threshold for k, v in
            skip_counts.items()}


def init_skip_counts(schema: Dict[str, Tuple[int, int]]):
    return {k: jnp.zeros(s, jnp.int32) for k, s in schema.items()}


def init_scores(schema: Dict[str, Tuple[int, int]]):
    return {k: jnp.zeros(s, jnp.float32) for k, s in schema.items()}
