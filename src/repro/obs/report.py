"""Run-log reporting: render and regression-diff telemetry JSONL.

``python -m repro.obs report <run>`` renders a run's manifest header, the
per-round table (history events: cycle, sim/wall clocks, metric, loss,
uplink/downlink), the straggler timeline (per-client completions and mean
staleness from the async completion stream, or per-round straggler
volumes from the sync volume stream), and the span/histogram census.

``python -m repro.obs diff <old> <new>`` compares two runs' summaries
within stated tolerances and exits nonzero on a regression — the CI gate
between a fresh run log and a committed baseline.  Either side may be a
run directory, an ``events.jsonl``, or a ``BENCH_observability.json``
(whose ``summary`` block is shaped like a run-log summary exactly so the
two compare uniformly).
"""
from __future__ import annotations

import json
import os
from typing import List, Optional, Tuple

#: metric keys a history row may carry, in display preference order
_METRICS = ("acc", "ce", "loss")


def load_events(path: str) -> List[dict]:
    """Events from a run log: a directory (its ``events.jsonl``), a
    ``.jsonl`` file, or a ``BENCH_observability.json`` (no events, just
    the summary line)."""
    if os.path.isdir(path):
        path = os.path.join(path, "events.jsonl")
    with open(path) as f:
        if path.endswith(".json"):
            bench = json.load(f)
            rows = [{"kind": "manifest", **bench.get("manifest", {})}]
            if "summary" in bench:
                rows.append({"kind": "summary", **bench["summary"]})
            return rows
        return [json.loads(line) for line in f if line.strip()]


def _by_kind(events: List[dict], kind: str) -> List[dict]:
    return [e for e in events if e.get("kind") == kind]


def _first(events: List[dict], kind: str) -> dict:
    rows = _by_kind(events, kind)
    return rows[0] if rows else {}


def _metric_key(row: dict) -> Optional[str]:
    for k in _METRICS:
        if k in row:
            return k
    return None


def _fmt_table(headers: List[str], rows: List[List[str]]) -> str:
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    line = "  ".join(h.ljust(w) for h, w in zip(headers, widths))
    sep = "  ".join("-" * w for w in widths)
    body = ["  ".join(c.ljust(w) for c, w in zip(r, widths)) for r in rows]
    return "\n".join([line, sep] + body)


def summarize(events: List[dict]) -> dict:
    """The comparable summary of one run log: final metric, simulated
    wall-clock, byte accounting, and the event census ``diff`` gates on."""
    hist = _by_kind(events, "history")
    summary = _first(events, "summary")
    counters = summary.get("counters", {})
    gauges = summary.get("gauges", {})
    out = {
        "rounds": len(hist),
        "sim_time": hist[-1]["sim"] if hist else summary.get("sim_time"),
        "events": summary.get("events", len(events)),
        "uplink_mb": gauges.get("uplink_mb", summary.get("uplink_mb")),
        "downlink_mb": gauges.get("downlink_mb",
                                  summary.get("downlink_mb")),
        "counters": counters,
    }
    if hist:
        mk = _metric_key(hist[-1])
        if mk:
            out["metric_name"] = mk
            out["final_metric"] = hist[-1][mk]
    else:
        out["metric_name"] = summary.get("metric_name")
        out["final_metric"] = summary.get("final_metric")
    return out


def render(events: List[dict]) -> str:
    """The full human-readable report for one run log."""
    parts = []
    man = _first(events, "manifest")
    if man:
        keys = ("engine", "scheme", "family", "model", "kernels",
                "compression", "clients", "participation", "seed",
                "git_sha")
        parts.append("run manifest: " + "  ".join(
            f"{k}={man[k]}" for k in keys if k in man))

    hist = _by_kind(events, "history")
    if hist:
        mk = _metric_key(hist[0]) or "metric"
        headers = ["cycle", "cadence", "sim_time", "wall_s", mk, "loss",
                   "downlink_mb"]
        rows = []
        for h in hist:
            rows.append([
                str(h.get("cycle", "?")),
                str(h.get("record_cadence", "?")),
                f"{h.get('sim', float('nan')):.3f}",
                f"{h.get('wall', float('nan')):.2f}",
                f"{h.get(mk, float('nan')):.4f}",
                f"{h.get('loss', float('nan')):.4f}",
                f"{h.get('downlink_mb', float('nan')):.2f}",
            ])
        parts.append("per-round table\n" + _fmt_table(headers, rows))

    comps = _by_kind(events, "completion")
    if comps:
        per = {}
        for c in comps:
            d = per.setdefault(c["cid"], {"n": 0, "stale": 0.0})
            d["n"] += 1
            d["stale"] += c.get("stale", 0)
        rows = [[str(cid), str(d["n"]), f"{d['stale'] / d['n']:.2f}"]
                for cid, d in sorted(per.items())]
        parts.append("straggler timeline (async completions)\n"
                     + _fmt_table(["cid", "completions", "mean_staleness"],
                                  rows))
    vols = _by_kind(events, "volumes")
    if vols:
        rows = [[str(v.get("round", "?")), f"{v.get('sim', 0.0):.3f}",
                 " ".join(f"{x:.2f}" for x in v.get("volumes", []))]
                for v in vols]
        parts.append("straggler timeline (volumes per round)\n"
                     + _fmt_table(["round", "sim_time",
                                   "straggler_volumes"], rows))

    promos = _by_kind(events, "promotion")
    swaps = _by_kind(events, "swap")
    if promos or swaps:
        summary = _first(events, "summary")
        counters = summary.get("counters", {})
        hists = summary.get("hists", {})
        head = []
        for k in ("serve_requests", "serve_swaps", "serve_promotions",
                  "serve_rejections", "published_snapshots"):
            if k in counters:
                head.append(f"{k}={counters[k]}")
        parts.append("serving plane: " + "  ".join(head))
        rows = []
        for p in promos:
            rows.append([
                str(p.get("step", "?")), str(p.get("round", "?")),
                "promote" if p.get("promoted") else "reject",
                f"{p.get('metric', float('nan')):.4f}",
                "-" if p.get("served_metric") is None
                else f"{p['served_metric']:.4f}",
            ])
        if rows:
            parts.append("promotion decisions\n" + _fmt_table(
                ["step", "round", "decision", "metric", "served_metric"],
                rows))
        rows = [[str(s.get("step", "?")), str(s.get("round", "?")),
                 str(s.get("staleness", "?"))] for s in swaps]
        if rows:
            parts.append("hot swaps\n" + _fmt_table(
                ["step", "round", "staleness_rounds"], rows))
        for name in ("request_ms", "serve_staleness"):
            if name in hists:
                parts.append(f"{name}: " + json.dumps(hists[name],
                                                      sort_keys=True))

    spans = _by_kind(events, "span")
    if spans:
        agg = {}
        for s in spans:
            d = agg.setdefault(s.get("name", "?"), {"n": 0, "ms": 0.0})
            d["n"] += 1
            d["ms"] += s.get("wall_ms", 0.0)
        rows = [[name, str(d["n"]), f"{d['ms']:.1f}",
                 f"{d['ms'] / d['n']:.2f}"]
                for name, d in sorted(agg.items())]
        parts.append("span census\n" + _fmt_table(
            ["span", "count", "total_ms", "mean_ms"], rows))

    summary = _first(events, "summary")
    if summary:
        parts.append("summary counters: " + json.dumps(
            summary.get("counters", {}), sort_keys=True))
        if summary.get("hists"):
            parts.append("histograms: " + json.dumps(summary["hists"],
                                                     sort_keys=True))
    return "\n\n".join(parts) if parts else "(empty run log)"


#: (field, relative tolerance, direction) — ``+`` means larger-is-better
#: (a drop beyond tol regresses), ``-`` means smaller-is-better
_DIFF_FIELDS = (("final_metric", 0.05, "+"),
                ("sim_time", 0.25, "-"),
                ("uplink_mb", 0.25, "-"),
                ("downlink_mb", 0.25, "-"))


def diff(old_events: List[dict], new_events: List[dict],
         tol_scale: float = 1.0) -> Tuple[List[str], List[str]]:
    """Compare two run summaries; returns (report lines, regressions).

    Loss-like metrics (``ce``/``loss``) invert the metric direction.
    Fields absent on either side are reported but never gate.
    """
    old, new = summarize(old_events), summarize(new_events)
    lines, regressions = [], []
    for field, tol, direction in _DIFF_FIELDS:
        a, b = old.get(field), new.get(field)
        if a is None or b is None:
            lines.append(f"{field}: old={a} new={b} (not compared)")
            continue
        if field == "final_metric" and \
                old.get("metric_name") in ("ce", "loss"):
            direction = "-"
        tol = tol * tol_scale
        scale = max(abs(a), 1e-9)
        delta = (b - a) / scale
        bad = delta < -tol if direction == "+" else delta > tol
        verdict = "REGRESSION" if bad else "ok"
        lines.append(f"{field}: old={a:.4f} new={b:.4f} "
                     f"delta={delta * 100:+.1f}% tol={tol * 100:.0f}% "
                     f"[{verdict}]")
        if bad:
            regressions.append(field)
    return lines, regressions


def main_report(path: str) -> int:
    print(render(load_events(path)))
    return 0


def main_diff(old_path: str, new_path: str, tol_scale: float = 1.0) -> int:
    lines, regressions = diff(load_events(old_path), load_events(new_path),
                              tol_scale)
    print(f"diff {old_path} -> {new_path}")
    for line in lines:
        print("  " + line)
    if regressions:
        print(f"REGRESSION in: {', '.join(regressions)}")
        return 1
    print("no regressions")
    return 0
