"""CLI: ``python -m repro.obs report|diff``.

``report <run>`` renders one run log (directory, events.jsonl, or
BENCH_observability.json).  ``diff <old> <new>`` compares two and exits
nonzero on a regression outside the stated tolerances.
"""
from __future__ import annotations

import argparse
import sys

from repro.obs import report as R


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.obs",
                                description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("report", help="render one run log")
    rp.add_argument("run", help="run dir, events.jsonl, or BENCH json")

    dp = sub.add_parser("diff", help="regression-diff two run logs")
    dp.add_argument("old", help="baseline run log / BENCH json")
    dp.add_argument("new", help="candidate run log / BENCH json")
    dp.add_argument("--tol-scale", type=float, default=1.0,
                    help="multiply every tolerance (default 1.0)")

    args = p.parse_args(argv)
    if args.cmd == "report":
        return R.main_report(args.run)
    return R.main_diff(args.old, args.new, args.tol_scale)


if __name__ == "__main__":
    sys.exit(main())
