"""Observability: the engines' unified telemetry layer.

Zero-overhead-when-off (mirrors the ``REPRO_CONTRACTS`` arming pattern):
arm with ``REPRO_OBS=on`` or a session :func:`override`.  The
:class:`Recorder` is the single accounting surface — engine counters
(``events_processed``, ``agg_counter``, ``uplink_coords``, …) live here
and the old engine attributes are thin views.  Armed, it additionally
buffers dual-clock (sim + wall) events, spans, and histograms, flushed
to a JSONL event log + run manifest that ``python -m repro.obs
report|diff`` renders and regression-gates.
"""
from repro.obs.recorder import (  # noqa: F401
    Recorder,
    SIM_KINDS,
    enabled,
    env_profile_round,
    git_sha,
    override,
)
from repro.obs.report import (  # noqa: F401
    diff,
    load_events,
    render,
    summarize,
)
