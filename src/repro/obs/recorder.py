"""The telemetry recorder — the engines' single accounting surface.

Two layers, mirroring the ``REPRO_CONTRACTS`` arming pattern
(repro.analysis.contracts):

* **Accounting** (always on): counters, gauges, and device-scalar
  accumulators.  These ARE the engines' runtime bookkeeping —
  ``events_processed``, ``agg_counter``, ``uplink_coords``, … live here
  and the old engine attributes are thin property views.  Counter writes
  are plain dict arithmetic on host ints; ``accum`` adds device scalars
  eagerly WITHOUT syncing (the uplink-coords pattern: the value crosses
  to host exactly once, in :meth:`accum_value`, behind an
  ``expected_transfer``), so a disarmed recorder changes neither the
  engines' trajectories nor their host-transfer profile.
* **Emission** (armed only): dual-clock spans, histogram observations,
  and the JSONL event stream + run manifest sinks.  Armed via
  ``REPRO_OBS=on``, a session :func:`repro.obs.override`, or an explicit
  ``Recorder(armed=True)``.  Disarmed, every emission method is one
  boolean test and zero events are ever buffered or written.

Every event carries the **dual clock**: ``sim`` is the caller-supplied
simulated time (the engines' SimClock / round clock — deterministic, so
fixed-seed event streams are engine-comparable) and ``wall`` is host
``time.perf_counter`` relative to recorder construction (real, so spans
price what instrumentation and training actually cost).  Determinism
tests compare :meth:`sim_events` (wall fields stripped); profiling reads
the wall side.
"""
from __future__ import annotations

import contextlib
import json
import os
import subprocess
import threading
import time
from typing import Dict, List, Optional

import jax

from repro.analysis import contracts as CT

_TLS = threading.local()

#: event kinds whose payload is pure simulated-time/host arithmetic and
#: therefore engine-invariant for a fixed seed (the determinism wall in
#: tests/test_obs.py compares exactly these, wall clocks stripped)
SIM_KINDS = ("round", "span", "completion", "drop", "volumes")


def _env_enabled() -> bool:
    return os.environ.get("REPRO_OBS", "off").strip().lower() in (
        "on", "1", "true", "yes")


def enabled() -> bool:
    """Telemetry armed?  A session :func:`override` beats ``REPRO_OBS``."""
    ov = getattr(_TLS, "override", None)
    return _env_enabled() if ov is None else ov


@contextlib.contextmanager
def override(value: bool):
    """Force telemetry on/off for a scope (tests/benches flip in-process)."""
    prev = getattr(_TLS, "override", None)
    _TLS.override = bool(value)
    try:
        yield
    finally:
        _TLS.override = prev


def env_profile_round() -> Optional[int]:
    """Round index to capture a ``jax.profiler`` trace around
    (``REPRO_OBS_PROFILE=<round>``; unset/invalid = no trace)."""
    v = os.environ.get("REPRO_OBS_PROFILE", "").strip()
    try:
        return int(v)
    except ValueError:
        return None


def git_sha() -> str:
    """Current commit sha for the run manifest ("unknown" outside git)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


_NULL_CTX = contextlib.nullcontext()


class Recorder:
    """Counters + gauges + device accumulators (always) and dual-clock
    spans + histograms + JSONL event log + run manifest (armed only).

    One recorder per engine run (constructed in ``FLRun.__post_init__``);
    pass ``recorder=`` to share one across runs or to arm explicitly.
    """

    def __init__(self, armed: Optional[bool] = None,
                 manifest: Optional[dict] = None,
                 profile_round: Optional[int] = None,
                 profile_dir: str = "obs_profile"):
        self.armed = enabled() if armed is None else bool(armed)
        self.manifest: dict = dict(manifest or {})
        self.counters: Dict[str, int] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, List[float]] = {}
        self.events: List[dict] = []
        self._accums: Dict[str, jax.Array] = {}
        self._t0 = time.perf_counter()
        self.profile_round = env_profile_round() \
            if profile_round is None else profile_round
        self.profile_dir = profile_dir

    # -- accounting surface (always on) ---------------------------------
    def inc(self, name: str, n: int = 1) -> int:
        self.counters[name] = self.counters.get(name, 0) + n
        return self.counters[name]

    def set(self, name: str, value: int) -> None:
        self.counters[name] = value

    def set_max(self, name: str, value) -> None:
        self.counters[name] = max(self.counters.get(name, value), value)

    def count(self, name: str, default: int = 0) -> int:
        return self.counters.get(name, default)

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def accum(self, name: str, value) -> None:
        """Accumulate a DEVICE scalar eagerly — no host sync; the running
        sum stays on device until :meth:`accum_value`."""
        prev = self._accums.get(name)
        self._accums[name] = value if prev is None else prev + value

    def accum_raw(self, name: str, default=None):
        """The device accumulator itself, unsynced (legacy attribute
        views hand this out so callers can keep adding device-side)."""
        return self._accums.get(name, default)

    def accum_value(self, name: str, default: float = 0.0) -> float:
        """The one intended sync point for a device accumulator."""
        v = self._accums.get(name)
        if v is None:
            return default
        with CT.expected_transfer("obs.accum_value[" + name + "]"):
            return float(v)                    # repro: noqa[R3]

    # -- emission (armed only) ------------------------------------------
    def event(self, kind: str, *, sim: Optional[float] = None,
              **fields) -> None:
        """Append one telemetry event (host values only — emission inside
        a ``no_host_transfers`` section must never force a sync)."""
        if not self.armed:
            return
        ev: dict = {"kind": kind,
                    "wall": time.perf_counter() - self._t0}
        if sim is not None:
            ev["sim"] = sim
        ev.update(fields)
        self.events.append(ev)

    def observe(self, name: str, value: float) -> None:
        """Histogram observation (summarized at flush)."""
        if not self.armed:
            return
        self.hists.setdefault(name, []).append(value)

    def span(self, name: str, sim: Optional[float] = None, **tags):
        """Dual-clock span: emits one ``span`` event carrying the
        caller's sim time and the measured wall duration."""
        if not self.armed:
            return _NULL_CTX
        return self._span(name, sim, tags)

    @contextlib.contextmanager
    def _span(self, name, sim, tags):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.event("span", sim=sim, name=name,
                       wall_ms=(time.perf_counter() - t0) * 1e3, **tags)

    @contextlib.contextmanager
    def maybe_profile(self, round_idx: int):
        """Capture a ``jax.profiler`` trace around ONE chosen round
        (armed + ``profile_round`` match); otherwise free."""
        if not self.armed or self.profile_round is None or \
                round_idx != self.profile_round:
            yield
            return
        started = False
        try:
            jax.profiler.start_trace(self.profile_dir)
            started = True
        except Exception as e:               # backend without profiling
            self.event("profile_error", round=round_idx, error=str(e))
        try:
            yield
        finally:
            if started:
                try:
                    jax.profiler.stop_trace()
                    self.event("profile_trace", round=round_idx,
                               dir=self.profile_dir)
                except Exception as e:
                    self.event("profile_error", round=round_idx,
                               error=str(e))

    # -- views / sinks --------------------------------------------------
    def sim_events(self, kinds=SIM_KINDS) -> List[dict]:
        """Events of engine-invariant kinds with wall clocks stripped —
        what the fixed-seed determinism wall compares."""
        out = []
        for ev in self.events:
            if ev["kind"] not in kinds:
                continue
            out.append({k: v for k, v in ev.items()
                        if k not in ("wall", "wall_ms")})
        return out

    def hist_summary(self) -> Dict[str, dict]:
        out = {}
        for name, vals in self.hists.items():
            s = sorted(vals)
            n = len(s)
            out[name] = {"count": n, "min": s[0], "max": s[-1],
                         "mean": sum(s) / n,
                         "p50": s[n // 2],
                         "p90": s[min((9 * n) // 10, n - 1)],
                         "p99": s[min((99 * n) // 100, n - 1)]}
        return out

    def snapshot(self) -> dict:
        """Current accounting census: counters, gauges (device
        accumulators synced here), histogram summaries."""
        gauges = dict(self.gauges)
        for name in self._accums:
            gauges[name] = self.accum_value(name)
        return {"counters": dict(self.counters), "gauges": gauges,
                "hists": self.hist_summary()}

    def flush(self, out_dir: str) -> dict:
        """Write the run log: ``events.jsonl`` (manifest line, one line
        per event, summary line) + ``manifest.json``.  Returns paths."""
        os.makedirs(out_dir, exist_ok=True)
        events_path = os.path.join(out_dir, "events.jsonl")
        manifest_path = os.path.join(out_dir, "manifest.json")
        summary = self.snapshot()
        summary["events"] = len(self.events)
        with open(manifest_path, "w") as f:
            json.dump(self.manifest, f, indent=2, default=str)
        with open(events_path, "w") as f:
            f.write(json.dumps({"kind": "manifest", **self.manifest},
                               default=str) + "\n")
            for ev in self.events:
                f.write(json.dumps(ev, default=str) + "\n")
            f.write(json.dumps({"kind": "summary", **summary},
                               default=str) + "\n")
        return {"events": events_path, "manifest": manifest_path,
                "summary": summary}
