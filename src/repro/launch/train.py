"""Training driver (runs for real on CPU; same step code the dry-run lowers).

Integrates the full stack: config registry -> model zoo -> Helios
soft-training state -> optimizer -> checkpointing (restart-safe) -> data
pipeline.  Helios mask re-selection happens at cycle boundaries
(``--cycle-steps``), exactly like the FL runtime's begin/end_cycle.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduced \
      --steps 200 --batch 8 --seq 128 --volume 0.5 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.configs import (HeliosConfig, ShapeConfig, TrainConfig,
                           get_model_config, reduced as reduce_cfg)
from repro.core import soft_train as ST
from repro.data.synthetic import markov_tokens
from repro.launch import steps as S
from repro.models import default_runtime


def make_step(cfg, hcfg, tcfg, rt):
    return jax.jit(S.make_train_step(cfg, hcfg, tcfg, rt))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--volume", type=float, default=1.0,
                    help="Helios soft-training volume P (1.0 = full model)")
    ap.add_argument("--cycle-steps", type=int, default=20,
                    help="soft-training cycle length (mask re-selection)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_model_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    hcfg = HeliosConfig(enabled=True, contribution="grad_ema")
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 20))
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    rt = default_runtime(cfg, shape)

    step_fn = make_step(cfg, hcfg, tcfg, rt)
    state = S.init_train_state(jax.random.PRNGKey(args.seed), cfg, hcfg, tcfg)
    state["helios"] = ST.set_volume(state["helios"], args.volume)

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, start = restore(args.ckpt_dir, state)
        print(f"resumed from step {start}")

    data = markov_tokens(max(64, args.batch * 8), args.seq + 1,
                         cfg.padded_vocab, seed=args.seed)
    rng = np.random.default_rng(args.seed)

    n_params = sum(p.size for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M volume={args.volume} "
          f"steps={args.steps} tokens/step={args.batch * args.seq}")

    losses = []
    t0 = time.time()
    for i in range(start, args.steps):
        if hcfg.enabled and i % args.cycle_steps == 0:
            state["helios"] = ST.begin_cycle(state["helios"], hcfg)
        idx = rng.integers(0, len(data), args.batch)
        batch = {"tokens": jnp.asarray(data[idx, :args.seq])}
        if cfg.family == "vlm":
            n_img = cfg.num_image_tokens
            batch = {"tokens": jnp.asarray(data[idx, :args.seq - n_img]),
                     "image_embeds": jnp.asarray(
                         rng.normal(size=(args.batch, n_img, cfg.d_model)),
                         jnp.float32)}
        elif cfg.family == "encdec":
            batch["enc_embeds"] = jnp.asarray(
                rng.normal(size=(args.batch, args.seq, cfg.d_model)),
                jnp.float32)
        state, metrics = step_fn(state, batch)
        # keep the device scalar: converting every step would serialize
        # dispatch against execution — sync only at gated log/ckpt points
        losses.append(metrics["loss"])
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(f"step {i:5d} loss {float(losses[-1]):.4f} "  # repro: noqa[R3]
                  f"grad_norm {float(metrics['grad_norm']):.3f} "  # repro: noqa[R3]
                  f"({dt / max(1, len(losses)):.2f}s/step)", flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, i + 1, state,
                 metadata={"arch": cfg.name, "loss": float(losses[-1])})  # repro: noqa[R3]
    losses = [float(x) for x in losses]
    if args.ckpt_dir:
        save(args.ckpt_dir, args.steps, state, metadata={"arch": cfg.name})
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
