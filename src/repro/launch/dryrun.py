import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_HOST_DEVICES", "512"))
# ^ MUST run before any other import: jax locks the device count on first
#   init.  Smoke tests / benches never import this module and see 1 device.

# Multi-pod dry-run: lower + compile every (architecture x input shape) cell
# on the production mesh, without allocating a single parameter.
#
# For each cell we record: per-device HLO FLOPs/bytes (cost_analysis),
# memory_analysis, collective traffic parsed from the compiled HLO, and the
# three roofline terms (EXPERIMENTS.md §Dry-run / §Roofline read these JSON
# reports).
#
# Usage:
#   python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
#   python -m repro.launch.dryrun --all [--multi-pod] [--fl-round]

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, SHAPES, TrainConfig, HeliosConfig,
                           applicable, get_model_config, get_shape)
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh
from repro.models import decode_cache_specs, default_runtime
from repro.parallel import hlo_analysis as HA
from repro.parallel import sharding as SH

#: per-arch training overrides chosen to fit v5e HBM (DESIGN.md §5)
TRAIN_OVERRIDES = {
    "deepseek-v2-236b": dict(param_dtype="bfloat16", compute_dtype="bfloat16",
                             microbatches=16),
    "qwen1.5-32b": dict(param_dtype="bfloat16", compute_dtype="bfloat16",
                        microbatches=8),
    "qwen2.5-32b": dict(param_dtype="bfloat16", compute_dtype="bfloat16",
                        microbatches=8),
    "deepseek-7b": dict(param_dtype="bfloat16", compute_dtype="bfloat16",
                        microbatches=4),
    "codeqwen1.5-7b": dict(param_dtype="bfloat16", compute_dtype="bfloat16",
                           microbatches=4),
    "seamless-m4t-large-v2": dict(compute_dtype="bfloat16", microbatches=2),
    "granite-moe-1b-a400m": dict(compute_dtype="bfloat16", microbatches=2),
    "zamba2-1.2b": dict(compute_dtype="bfloat16", microbatches=4),
    "internvl2-1b": dict(compute_dtype="bfloat16", microbatches=2),
    "xlstm-125m": dict(compute_dtype="bfloat16", microbatches=2),
}

SERVE_DTYPE = "bfloat16"


def _tcfg(arch: str, kind: str) -> TrainConfig:
    if kind == "train":
        return TrainConfig(**TRAIN_OVERRIDES.get(arch, {}))
    return TrainConfig(param_dtype=SERVE_DTYPE, compute_dtype=SERVE_DTYPE)


def _moe_groups(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("data", 1) * sizes.get("pod", 1)


def _runtime(cfg, shape, mesh) -> dict:
    from jax.sharding import PartitionSpec as P
    rt = default_runtime(cfg, shape, moe_groups=_moe_groups(mesh))
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    rt["act_spec"] = P(batch_axes, None, None)
    rt["logits_spec"] = P(batch_axes, None, "model")
    # GQA archs whose kv_heads don't divide the model axis: pin K/V
    # batch-sharded (gathered once per layer, not once per chunk)
    if shape.kind == "train":
        # save attention outputs across the layer scan: no S^2 recompute in
        # the backward pass at +1 residual-sized stash per layer (§Perf C)
        rt["remat_policy"] = "save_attn"
    msize = dict(mesh.shape).get("model", 1)
    if cfg.num_kv_heads % msize != 0 or cfg.num_kv_heads < msize:
        rt["kv_spec"] = P(batch_axes, None, None, None)
        if shape.kind == "decode" and shape.seq_len % msize == 0:
            # decode: keep the cache SHARDED over seq (distributed
            # flash-decoding) — never re-gather it per step
            rt["decode_kv_spec"] = P(batch_axes, "model", None, None)
    return rt


def analyze(lowered, compiled, cfg, shape, mesh) -> dict:
    from repro.parallel.hlo_cost import pattern_bytes, weighted_cost
    cost = HA.cost_analysis_dict(compiled)
    # trip-count-weighted re-walk of the HLO (lax.scan bodies count x trips;
    # XLA's cost_analysis counts them once — see parallel/hlo_cost.py)
    hlo_text = compiled.as_text()
    wc = weighted_cost(hlo_text)
    flops = wc["flops"]
    hbm = wc["bytes"]

    # flash-kernel adjustment (EXPERIMENTS.md §Perf): the HBM traffic inside
    # the "chunked_attention" scope is score-block round-tripping that the
    # validated Pallas kernel keeps in VMEM; its true HBM IO is q/k/v/o once.
    attn_bytes = pattern_bytes(hlo_text, "chunked_attention")
    flash_io = 0.0
    if attn_bytes and cfg.num_heads:
        n_dev = mesh.devices.size
        per_tensor = (shape.global_batch * shape.seq_len * cfg.num_heads *
                      cfg.resolved_head_dim * 2)
        layers = cfg.num_layers + (cfg.dec_layers if cfg.is_encdec else 0)
        flash_io = 4.0 * per_tensor * layers / n_dev
    hbm_flash = hbm - attn_bytes + flash_io
    coll = {k: float(v) for k, v in wc["collectives"].items()}
    total_coll = float(wc["collective_bytes"])
    n_dev = mesh.devices.size
    rl = HA.Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=total_coll,
                     num_devices=n_dev,
                     model_flops=HA.model_flops_for_cell(cfg, shape))
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
        # newer jaxlib dropped peak_memory_in_bytes; the CPU backend's temp
        # accounting is NOT a per-device HBM peak (it reports the whole
        # unoptimized buffer set), so peak_bytes is only emitted when the
        # backend reports a real peak — absent keys keep consumers'
        # .get(key, default) semantics meaningful
        mem_info = {k: v for k, v in mem_info.items() if v is not None}
    except Exception:                                      # CPU backend quirk
        mem_info = {}
    return {"roofline": rl.row(), "collectives": coll, "memory": mem_info,
            "hlo_flops": flops, "hlo_bytes": hbm,
            "attn_score_bytes": attn_bytes,
            "hlo_bytes_flash_adjusted": hbm_flash,
            "t_memory_flash_s": hbm_flash / HA.HBM_BW,
            "xla_flops_unweighted": float(cost.get("flops", 0.0)),
            "collective_bytes": total_coll, "num_devices": n_dev}


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                fl_round: bool = False, verbose: bool = True) -> dict:
    cfg = get_model_config(arch)
    shape = get_shape(shape_name)
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    tcfg = _tcfg(arch, shape.kind)
    hcfg = HeliosConfig(enabled=shape.kind == "train",
                        contribution="grad_ema")
    rt = _runtime(cfg, shape, mesh)
    if shape.kind != "train":
        rt["act_spec"] = rt["logits_spec"] = None
    t0 = time.time()

    with mesh:
        if shape.kind == "train" and fl_round:
            n_clients = 2 if multi_pod else 1
            step = S.make_fl_round_step(cfg, hcfg, tcfg, rt, n_clients)
            state = S.abstract_fl_state(cfg, hcfg, tcfg, n_clients)
            in_sh = S.fl_state_shardings(cfg, state, mesh)
            batch = S.fl_abstract_batch(cfg, shape, tcfg, n_clients,
                                        local_steps=4)
            bsh = jax.tree.map(
                lambda l: jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec(
                        "pod" if multi_pod else None, None,
                        "data" if l.shape[2] % 16 == 0 else None,
                        *([None] * (l.ndim - 3)))), batch)
            metr_abs = jax.eval_shape(step, state, batch)[1]
            jitted = jax.jit(step, in_shardings=(in_sh, bsh),
                             out_shardings=(in_sh,
                                            SH.replicated(metr_abs, mesh)))
            lowered = jitted.lower(state, batch)
        elif shape.kind == "train":
            step = S.make_train_step(cfg, hcfg, tcfg, rt)
            state = S.abstract_train_state(cfg, hcfg, tcfg)
            in_sh = S.train_state_shardings(cfg, state, mesh)
            batch = S.abstract_batch(cfg, shape, tcfg)
            bsh = SH.batch_shardings(batch, mesh, shape.global_batch)
            # new state keeps the input state's shardings (no replication)
            metr_abs = jax.eval_shape(step, state, batch)[1]
            jitted = jax.jit(step, in_shardings=(in_sh, bsh),
                             out_shardings=(in_sh,
                                            SH.replicated(metr_abs, mesh)),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, batch)
        elif shape.kind == "prefill":
            step = S.make_prefill_step(cfg, rt)
            params = S.abstract_params_typed(cfg, tcfg)
            psh = SH.param_shardings(S.logical_axes(cfg), params, mesh,
                                     SH.rules_for(cfg))
            batch = S.abstract_batch(cfg, shape, tcfg)
            bsh = SH.batch_shardings(batch, mesh, shape.global_batch)
            # outputs: (logits, cache) — cache MUST be sharded or XLA
            # replicates seq_len x layers of KV per device (EXPERIMENTS.md
            # §Perf cell A)
            out_abs = jax.eval_shape(step, params, batch)
            osh = (SH.batch_shardings(out_abs[0], mesh, shape.global_batch),
                   SH.cache_shardings(out_abs[1], mesh, shape.global_batch,
                                      shape.seq_len, cfg.num_kv_heads))
            jitted = jax.jit(step, in_shardings=(psh, bsh),
                             out_shardings=osh)
            lowered = jitted.lower(params, batch)
        else:                                              # decode
            step = S.make_serve_step(cfg, rt)
            params = S.abstract_params_typed(cfg, tcfg)
            psh = SH.param_shardings(S.logical_axes(cfg), params, mesh,
                                     SH.rules_for(cfg, kind="decode"))
            cache = decode_cache_specs(cfg, shape, rt,
                                       param_dtype=S._dt(tcfg.param_dtype))
            csh = SH.cache_shardings(cache, mesh, shape.global_batch,
                                     shape.seq_len, cfg.num_kv_heads)
            token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            tsh = SH.batch_shardings(token, mesh, shape.global_batch)
            out_abs = jax.eval_shape(step, params, token, cache)
            osh = (SH.batch_shardings(out_abs[0], mesh, shape.global_batch),
                   SH.cache_shardings(out_abs[1], mesh, shape.global_batch,
                                      shape.seq_len, cfg.num_kv_heads))
            # donate the cache: in-place update, no double buffering
            jitted = jax.jit(step, in_shardings=(psh, tsh, csh),
                             out_shardings=osh, donate_argnums=(2,))
            lowered = jitted.lower(params, token, cache)

        compiled = lowered.compile()

    rec = {"arch": arch, "shape": shape_name, "status": "ok",
           "multi_pod": multi_pod, "fl_round": fl_round,
           "mesh": list(mesh.devices.shape),
           "compile_s": round(time.time() - t0, 1)}
    rec.update(analyze(lowered, compiled, cfg, shape, mesh))
    if verbose:
        r = rec["roofline"]
        print(f"[{arch} x {shape_name} x {'multi' if multi_pod else 'single'}"
              f"{' fl' if fl_round else ''}] compile={rec['compile_s']}s "
              f"bottleneck={r['bottleneck']} "
              f"t=(c {r['t_compute_s']:.3e}, m {r['t_memory_s']:.3e}, "
              f"x {r['t_collective_s']:.3e})s useful={r['useful_ratio']:.2f}",
              flush=True)
        print(f"  memory: {rec['memory']}", flush=True)
        print(f"  collectives: {rec['collectives']}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fl-round", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    records = []
    for arch, shape in cells:
        try:
            rec = dryrun_cell(arch, shape, multi_pod=args.multi_pod,
                              fl_round=args.fl_round)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
        records.append(rec)
        tag = ("multi" if args.multi_pod else "single") + \
            ("_fl" if args.fl_round else "")
        fname = os.path.join(args.out, f"{arch}_{shape}_{tag}.json")
        with open(fname, "w") as f:
            json.dump(rec, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(records)} cells")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
