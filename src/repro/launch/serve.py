"""Serve-while-you-train: batched inference + lock-free checkpoint hot-swap.

The serving plane of the reproduction.  Three pieces compose into the
"traffic against the live global model" story (the benchmarks/examples
drive them; ``python -m repro.launch.serve`` remains the standalone
single-shot generation bench):

* :class:`GenerationServer` — the batched-inference path: jitted prefill +
  decode (the cached seams in :mod:`repro.models.api`) with the params
  tree as a TRACED argument, so swapping snapshots never recompiles, and
  ``mask=ones`` full-volume masks threaded through the same kernel seam
  training uses (``kernels="pallas"`` routes the Pallas masked kernels,
  interpret mode on CPU).
* :class:`ServeLoop` — lock-free hot-swap serving.  The training loop
  publishes atomic snapshots (``FLRun.publish_dir`` -> ``checkpoint.save``:
  tmp write + fsync + ``os.replace``); :meth:`ServeLoop.poll` picks up new
  steps behind an eval-gated promotion rule (promote only if the held-out
  metric does not regress beyond ``tol``).  The REQUEST path takes zero
  locks: a swap is one GIL-atomic rebind of the ``_served`` reference
  between jitted calls, never mid-program, and a request reads that
  reference exactly once.  Partially-written snapshots are unobservable by
  construction — in-flight ``*.tmp`` files never match the checkpoint key
  pattern (tests/test_serve.py pins the kill-mid-write case).
* :class:`PoissonTraffic` + :func:`run_traffic` — a deterministic open-loop
  Poisson load generator: seeded exponential inter-arrivals fix the arrival
  schedule, per-request latency is measured completion-minus-arrival (so
  queueing delay under overload is priced in, the open-loop semantics).

Telemetry rides the shared :class:`repro.obs.Recorder`: ``request_ms`` /
``serve_staleness`` histograms, ``serve_requests`` / ``serve_swaps`` /
``serve_promotions`` / ``serve_rejections`` counters, and ``swap`` /
``promotion`` events, so ``python -m repro.obs report`` covers the serving
plane next to the training rounds.  Counter keys are single-writer (the
serving thread); the training thread writes its own keys — the GIL makes
the shared event list safe without a lock on either hot path.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --reduced \
      --batch 8 --prompt-len 64 --gen 32 [--ckpt-dir /tmp/fl_run]
"""
from __future__ import annotations

import argparse
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as CKPT
from repro.configs import ShapeConfig, get_model_config, reduced as reduce_cfg
from repro.configs.base import ModelConfig
from repro.data.synthetic import markov_tokens
from repro.models import build, default_runtime, make_full_masks
from repro.obs import recorder as OBS


def serve_batch(cfg: ModelConfig, prompts: np.ndarray,
                rng: np.random.Generator) -> Dict[str, jnp.ndarray]:
    """Model-input dict for a prompt batch, including the extra streams
    the vlm/encdec families need."""
    batch = {"tokens": jnp.asarray(prompts)}
    n, s = prompts.shape
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(n, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    elif cfg.family == "encdec":
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(n, s, cfg.d_model)), jnp.float32)
    return batch


class GenerationServer:
    """Batched greedy generation: ONE jitted prefill + ONE jitted decode
    program for the (batch, prompt_len) cell, params as a traced argument.

    ``mask=ones`` full-volume masks go through the exact kernel seam the
    federated engines train through (``kernels="pallas"`` -> the Pallas
    masked-matmul / flash-attention path), so the serving plane exercises
    the training substrate rather than a separate inference stack.
    """

    def __init__(self, cfg: ModelConfig, batch: int, prompt_len: int,
                 gen: int = 8, kernels: str = "reference",
                 mask_block: int = 128):
        if gen < 1:
            raise ValueError(f"gen must be >= 1, got {gen}")
        self.cfg = cfg
        self.gen = gen
        api = build(cfg)
        if api.prefill_fn is None:
            raise ValueError(f"family {cfg.family!r} has no prefill/decode "
                             "serving path")
        shape = ShapeConfig("serve", "prefill", prompt_len, batch)
        rt = default_runtime(cfg, shape)
        rt["kernels"] = kernels
        rt["mask_block"] = mask_block
        masks = make_full_masks(cfg)
        self._prefill = jax.jit(
            lambda p, b: api.prefill_fn(p, b, cfg, rt, masks))
        self._decode = jax.jit(
            lambda p, t, c: api.decode_fn(p, t, c, cfg, rt, masks))

    def prefill(self, params, batch):
        return self._prefill(params, batch)

    def decode(self, params, token, cache):
        return self._decode(params, token, cache)

    def __call__(self, params, batch) -> jnp.ndarray:
        """Greedy-decode ``gen`` tokens; returns (B, gen) int32."""
        logits, cache = self._prefill(params, batch)
        token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out = [token]
        for _ in range(self.gen - 1):
            logits, cache = self._decode(params, token, cache)
            token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out.append(token)
        return jnp.concatenate(out, axis=1)

    def programs(self) -> Dict[str, int]:
        """{seam: compiled-program count} — the serving twin of the engine
        compile budgets: both must stay 1 across every hot swap (swap =
        new params leaves, same treedef/shapes/dtypes => cache hit)."""
        return {"prefill": self._prefill._cache_size(),
                "decode": self._decode._cache_size()}


@dataclasses.dataclass(frozen=True)
class _Served:
    """The immutable currently-served snapshot (swap = rebind, not mutate)."""
    step: int
    round: int
    params: Any
    metric: Optional[float]


class ServeLoop:
    """Checkpoint hot-swap serving with an eval-gated promotion rule.

    ``poll()`` (swap path, may block on restore + held-out eval) and
    ``handle()`` (request path, lock-free) are designed to run on the SAME
    serving thread between requests; the training loop publishes from its
    own thread through ``checkpoint.save``'s atomic rename.  ``handle``
    reads ``self._served`` exactly once — the GIL makes that reference load
    atomic, and a concurrent ``poll`` only ever REBINDS it to a new
    immutable :class:`_Served`, so a request always computes against one
    complete snapshot.

    Promotion rule: the first complete snapshot is always promoted (it
    seeds the baseline); afterwards a candidate is promoted only if its
    held-out metric does not regress beyond ``tol`` against the CURRENTLY
    SERVED snapshot's metric (``higher_is_better`` orients the
    comparison).  Rejected steps are remembered so a bad snapshot is
    evaluated once, not on every poll.
    """

    def __init__(self, ckpt_dir: str, template_params: Any,
                 request_fn: Callable[[Any, Any], Any],
                 eval_fn: Optional[Callable[[Any], float]] = None,
                 higher_is_better: bool = False, tol: float = 0.0,
                 recorder: Optional[OBS.Recorder] = None):
        self.ckpt_dir = ckpt_dir
        self.template = template_params
        self.request_fn = request_fn
        self.eval_fn = eval_fn
        self.higher_is_better = higher_is_better
        self.tol = float(tol)
        self.rec = recorder if recorder is not None else OBS.Recorder()
        self._served: Optional[_Served] = None
        self._last_decided_step: Optional[int] = None
        self.latest_round: int = 0         # newest PUBLISHED round seen

    # -- swap path (never on the request path) --------------------------
    def poll(self) -> bool:
        """Check for a newer published snapshot; eval-gate and maybe swap.
        Returns True iff a swap happened."""
        step = CKPT.latest_step(self.ckpt_dir)
        if step is None or step == self._last_decided_step:
            return False
        try:
            meta = CKPT.metadata(self.ckpt_dir, step)
            params, _ = CKPT.restore(self.ckpt_dir, self.template, step=step)
        except FileNotFoundError:
            # the publisher GC'd this step between listdir and read; a
            # newer complete snapshot exists — pick it up next poll
            self.rec.inc("serve_poll_misses")
            return False
        rnd = int(meta.get("round", step))
        self.latest_round = max(self.latest_round, rnd)
        self._last_decided_step = step
        metric = float(self.eval_fn(params)) if self.eval_fn else None
        promoted = self._served is None or metric is None or \
            self._gate(metric, self._served.metric)
        self.rec.inc("serve_promotions" if promoted else "serve_rejections")
        self.rec.event("promotion", step=step, round=rnd, promoted=promoted,
                       metric=metric,
                       served_metric=None if self._served is None
                       else self._served.metric)
        if not promoted:
            return False
        self._served = _Served(step, rnd, params, metric)
        self.rec.inc("serve_swaps")
        self.rec.event("swap", step=step, round=rnd,
                       staleness=self.latest_round - rnd)
        return True

    def _gate(self, candidate: float, served: Optional[float]) -> bool:
        if served is None:
            return True
        if self.higher_is_better:
            return candidate >= served - self.tol
        return candidate <= served + self.tol

    # -- request path (lock-free) ---------------------------------------
    def handle(self, batch):
        """Serve one request against the current snapshot.  One reference
        read, zero locks; blocks only on the response itself (the
        request's own sync point)."""
        served = self._served                  # the one atomic read
        if served is None:
            raise RuntimeError(
                f"nothing promoted yet (no checkpoints in {self.ckpt_dir}?)")
        out = self.request_fn(served.params, batch)
        out = jax.block_until_ready(out)
        self.rec.inc("serve_requests")
        self.rec.observe("serve_staleness", self.latest_round - served.round)
        return out

    @property
    def served_step(self) -> Optional[int]:
        s = self._served
        return None if s is None else s.step

    @property
    def served_round(self) -> Optional[int]:
        s = self._served
        return None if s is None else s.round

    @property
    def served_metric(self) -> Optional[float]:
        s = self._served
        return None if s is None else s.metric


def make_ce_eval(cfg: ModelConfig, held_out: Dict[str, jnp.ndarray],
                 rt: Optional[dict] = None) -> Callable[[Any], float]:
    """Held-out cross-entropy gate for token-LM serving (lower is better;
    pair with ``higher_is_better=False``).  One jitted program, params
    traced — the gate never recompiles across snapshots."""
    api = build(cfg)
    f = jax.jit(lambda p: api.loss_fn(p, held_out, cfg,
                                      rt or default_runtime(cfg), None))
    return lambda params: float(f(params))


# ---------------------------------------------------------------------------
# deterministic Poisson load generation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PoissonTraffic:
    """Open-loop Poisson arrivals: the schedule (cumulative arrival times
    in seconds) is fixed by the seed, independent of service times."""

    rate_hz: float
    seed: int = 0

    def schedule(self) -> Iterator[float]:
        if self.rate_hz <= 0:
            raise ValueError(f"rate_hz must be > 0, got {self.rate_hz}")
        rng = np.random.default_rng((self.seed, 0x7AFF1C))
        t = 0.0
        while True:
            t += rng.exponential(1.0 / self.rate_hz)
            yield t


def run_traffic(serve: ServeLoop, traffic: PoissonTraffic,
                make_batch: Callable[[int], Any],
                should_stop: Callable[[], bool],
                min_requests: int = 1,
                max_requests: Optional[int] = None,
                poll: bool = True) -> Dict[str, Any]:
    """Drive the open-loop arrival schedule against ``serve`` until
    ``should_stop()`` (and at least ``min_requests`` served).

    Latency per request = completion - SCHEDULED arrival (wall clock), so
    a server that falls behind accrues queueing delay instead of quietly
    slowing the arrival process down.  ``poll=True`` checks for a new
    snapshot between requests — on the serving thread, never under a lock.
    """
    sched = traffic.schedule()
    lat_ms: List[float] = []
    t0 = time.perf_counter()
    n = 0
    while not (should_stop() and n >= min_requests):
        if max_requests is not None and n >= max_requests:
            break
        arrival = next(sched)
        now = time.perf_counter() - t0
        if arrival > now:
            time.sleep(arrival - now)
        serve.handle(make_batch(n))
        done = time.perf_counter() - t0
        ms = (done - arrival) * 1e3
        lat_ms.append(ms)
        serve.rec.observe("request_ms", ms)
        if poll:
            serve.poll()
        n += 1
    wall = time.perf_counter() - t0
    return {"requests": n, "wall_s": wall,
            "requests_per_sec": n / max(wall, 1e-9),
            "offered_rate_hz": traffic.rate_hz, "latency_ms": lat_ms}


def serve_while_training(train_fn: Callable[[], Any], serve: ServeLoop,
                         traffic: PoissonTraffic,
                         make_batch: Callable[[int], Any],
                         min_requests: int = 1,
                         max_requests: Optional[int] = None,
                         final_poll: bool = True) -> Dict[str, Any]:
    """Run ``train_fn`` on a background thread while the calling thread
    serves traffic; returns the traffic stats.  Training exceptions
    propagate after the traffic loop drains."""
    err: List[BaseException] = []

    def target():
        try:
            train_fn()
        except BaseException as e:          # re-raised on the caller below
            err.append(e)

    th = threading.Thread(target=target, name="fl-train", daemon=True)
    th.start()
    try:
        stats = run_traffic(serve, traffic, make_batch,
                            should_stop=lambda: not th.is_alive(),
                            min_requests=min_requests,
                            max_requests=max_requests)
    finally:
        th.join()
    if err:
        raise err[0]
    if final_poll:
        serve.poll()                        # pick up the last-round publish
    return stats


# ---------------------------------------------------------------------------
# CLI: the standalone single-shot generation bench
# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernels", default="reference",
                    choices=("reference", "pallas"))
    ap.add_argument("--ckpt-dir", default=None,
                    help="serve the latest published snapshot from a "
                         "training run's publish_dir instead of fresh init")
    args = ap.parse_args(argv)

    cfg = get_model_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    srv = GenerationServer(cfg, args.batch, args.prompt_len, gen=args.gen,
                           kernels=args.kernels)

    from repro.models import init_params
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt_dir:
        params, step = CKPT.restore(args.ckpt_dir, params)
        print(f"restored snapshot step {step} from {args.ckpt_dir}")
    rng = np.random.default_rng(args.seed)
    prompts = markov_tokens(args.batch, args.prompt_len, cfg.padded_vocab,
                            seed=args.seed)
    batch = serve_batch(cfg, prompts, rng)

    t0 = time.time()
    logits, cache = srv.prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch} x {args.prompt_len} tokens in "
          f"{t_prefill:.2f}s")

    token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [token]
    decoded = args.gen - 1
    t0 = time.time()
    for _ in range(decoded):
        logits, cache = srv.decode(params, token, cache)
        token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(token)
    # tok/s semantics: intermediate steps stay async-dispatched (syncing
    # each logits tensor would serialize dispatch against execution and
    # understate throughput); the clock stops against the BLOCKED final
    # token only.  --gen 1 decodes nothing: dt would be ~0 and the rate a
    # 0/0 artifact, so the figure is skipped rather than fabricated.
    if decoded:
        token.block_until_ready()
        dt = time.time() - t0
        print(f"decode: {args.batch} x {decoded} tokens in {dt:.2f}s "
              f"({args.batch * decoded / max(dt, 1e-9):.1f} tok/s)")
    else:
        print("decode: skipped (--gen 1 is prefill-only; tok/s undefined)")
    toks = jnp.concatenate(generated, axis=1)
    print("sample:", np.asarray(toks[0])[:16].tolist())
    assert bool(jnp.all(toks >= 0)) and bool(jnp.all(toks < cfg.padded_vocab))
    return toks


if __name__ == "__main__":
    main()
