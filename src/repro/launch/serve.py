"""Serving driver: batched prefill + decode loop with KV/SSM caches.

# repro: noqa[R6] — standalone CLI entry point exercised only by tests;
kept as the serving surface (tracked in ROADMAP.md).

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --reduced \
      --batch 8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeConfig, get_model_config, reduced as reduce_cfg
from repro.data.synthetic import markov_tokens
from repro.models import build, default_runtime


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_model_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    api = build(cfg)
    shape = ShapeConfig("serve", "prefill", args.prompt_len, args.batch)
    rt = default_runtime(cfg, shape)

    from repro.models import init_params
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    prompts = markov_tokens(args.batch, args.prompt_len, cfg.padded_vocab,
                            seed=args.seed)

    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.family == "vlm":
        n_img = cfg.num_image_tokens
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, n_img, cfg.d_model)), jnp.float32)
    elif cfg.family == "encdec":
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, args.prompt_len, cfg.d_model)),
            jnp.float32)

    prefill = jax.jit(lambda p, b: api.prefill_fn(p, b, cfg, rt, None))
    decode = jax.jit(lambda p, t, c: api.decode_fn(p, t, c, cfg, rt, None))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch} x {args.prompt_len} tokens in "
          f"{t_prefill:.2f}s")

    token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [token]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, token, cache)
        token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(token)
    token.block_until_ready()
    dt = time.time() - t0
    toks = jnp.concatenate(generated, axis=1)
    print(f"decode: {args.batch} x {args.gen} tokens in {dt:.2f}s "
          f"({args.batch * (args.gen - 1) / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", np.asarray(toks[0])[:16].tolist())
    assert bool(jnp.all(toks >= 0)) and bool(jnp.all(toks < cfg.padded_vocab))
    return toks


if __name__ == "__main__":
    main()
