"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state.  Single pod: (16, 16) = 256 chips
(data, model).  Multi-pod: (2, 16, 16) = 512 chips (pod, data, model) — the
"pod" axis is the FL-client axis in the Helios datacenter mapping
(DESIGN.md §2).
"""
from __future__ import annotations

import os

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    # REPRO_MESH="4x4" / "2x2x4" overrides the chip count for scaled-down CI
    # runs of the same code path (tests/test_dryrun_small.py).
    override = os.environ.get("REPRO_MESH")
    if override:
        shape = tuple(int(x) for x in override.split("x"))
        axes = ("pod", "data", "model") if len(shape) == 3 else \
            ("data", "model")
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_client_mesh(max_shards: int | None = None):
    """1-D ``("clients",)`` mesh for the client-sharded FL engine.

    Uses every visible device by default; ``max_shards`` caps the axis so a
    small cohort doesn't spread one client per device and pad the rest (the
    sharded engine pads the cohort up to a multiple of the axis size).
    Validated on CPU via the ``REPRO_HOST_DEVICES``-forced host-device
    pattern (tests/test_sharded_engine.py, benchmarks sharded_population).
    """
    devs = jax.devices()
    n = len(devs)
    if max_shards is not None:
        n = max(1, min(n, max_shards))
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("clients",))


def make_debug_mesh(n_devices: int | None = None, *, multi_pod: bool = False):
    """Small mesh over however many (host) devices exist — used by tests."""
    n = n_devices or len(jax.devices())
    if multi_pod and n >= 8:
        return jax.make_mesh((2, 2, n // 4), ("pod", "data", "model"))
    if n >= 4:
        return jax.make_mesh((2, n // 2), ("data", "model"))
    return jax.make_mesh((1, n), ("data", "model"))
