"""Compiled step factories: train_step / prefill_step / serve_step /
fl_round_step, plus abstract state builders and sharding trees.

``train_step`` integrates Helios as a first-class feature: the state carries
the soft-training masks + contribution scores; masked units are excluded from
the forward pass (zero grads) and from optimizer updates (no decay drift),
and per-unit |grad| scores accumulate via EMA for the next cycle's selection
(mask RE-SELECTION happens at round boundaries on the host — cheap, O(units)).

``fl_round_step`` is the datacenter FL mapping: params are STACKED per client
(leading dim sharded over the "pod" axis -> each pod holds only its own
replica), every client runs E local steps (lax.scan), then Eq. 10
alpha-weighted aggregation collapses the client dim — compiling to one
all-reduce over the pod axis per round (local-SGD round fusion).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import (HeliosConfig, ModelConfig, ShapeConfig,
                                TrainConfig)
from repro.core import contribution as CONTRIB
from repro.core import masking as MK
from repro.core import soft_train as ST
from repro.models import (abstract_params, build, input_specs,
                          logical_axes)
from repro.optim import (apply_updates, clip_by_global_norm, make_optimizer,
                         warmup_cosine_schedule)
from repro.parallel import sharding as SH

# ---------------------------------------------------------------------------
# dtype policy
# ---------------------------------------------------------------------------

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
           "float16": jnp.float16}


def _dt(name: str):
    return _DTYPES[name]


def abstract_params_typed(cfg: ModelConfig, tcfg: TrainConfig):
    return abstract_params(cfg, _dt(tcfg.param_dtype))


def make_opt(cfg: ModelConfig, tcfg: TrainConfig):
    sched = warmup_cosine_schedule(tcfg.learning_rate, tcfg.warmup_steps,
                                   tcfg.total_steps)
    return make_optimizer(tcfg.optimizer, sched, b1=tcfg.beta1, b2=tcfg.beta2,
                          eps=tcfg.eps, weight_decay=tcfg.weight_decay)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, hcfg: HeliosConfig, tcfg: TrainConfig,
                    rt: dict):
    api = build(cfg)
    axes = logical_axes(cfg)
    schema = api.mask_schema
    opt = make_opt(cfg, tcfg)
    cdt = _dt(tcfg.compute_dtype)

    def loss_fn(params, batch, masks):
        p = jax.tree.map(lambda t: t.astype(cdt) if t.dtype == jnp.float32
                         and cdt != jnp.float32 else t, params)
        return api.loss_fn(p, batch, cfg, rt, masks)

    def train_step(state, batch):
        params = state["params"]
        masks = state["helios"]["masks"] if hcfg.enabled else None

        if tcfg.microbatches > 1:
            m = tcfg.microbatches
            batch_r = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                batch)

            def mb(carry, b):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, b, masks)
                g_acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32),
                                     g_acc, g)
                return (g_acc, l_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (gsum, lsum), _ = jax.lax.scan(mb, (zeros, 0.0), batch_r)
            grads = jax.tree.map(lambda g: g / m, gsum)
            loss = lsum / m
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, masks)

        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        updates, opt_state = opt.update(grads, state["opt"], params,
                                        state["step"])
        if hcfg.enabled:
            um = MK.expand_masks(axes, masks, updates)
            updates = MK.apply_mask_tree(updates, um)
        params = apply_updates(params, updates)

        helios = state["helios"]
        if hcfg.enabled:
            snew = (CONTRIB.cnn_unit_scores(grads, schema)
                    if cfg.family == "cnn"
                    else ST.grad_scores(grads, axes, schema))
            helios = {**helios,
                      "scores": {k: hcfg.contribution_ema * helios["scores"][k]
                                 + (1 - hcfg.contribution_ema) * snew[k]
                                 for k in snew}}

        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1, "helios": helios}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def abstract_train_state(cfg: ModelConfig, hcfg: HeliosConfig,
                         tcfg: TrainConfig):
    params = abstract_params(cfg, _dt(tcfg.param_dtype))
    opt = make_opt(cfg, tcfg)
    opt_state = jax.eval_shape(opt.init, params)
    api = build(cfg)
    helios = jax.eval_shape(
        functools.partial(ST.init_state, api.mask_schema, 1.0, 0))
    return {"params": params, "opt": opt_state,
            "step": jax.ShapeDtypeStruct((), jnp.int32), "helios": helios}


def train_state_shardings(cfg: ModelConfig, state_abs, mesh):
    axes = logical_axes(cfg)
    pshard = SH.param_shardings(axes, state_abs["params"], mesh,
                                SH.rules_for(cfg))
    # moment buffers mirror the params tree -> inherit param shardings
    if isinstance(state_abs["opt"], dict) and \
            set(state_abs["opt"]) <= {"m", "v"}:
        opt_shard = {k: pshard for k in state_abs["opt"]}
    else:
        opt_shard = SH.replicated(state_abs["opt"], mesh)
    return {"params": pshard, "opt": opt_shard,
            "step": SH.replicated(state_abs["step"], mesh),
            "helios": SH.replicated(state_abs["helios"], mesh)}


def init_train_state(key, cfg: ModelConfig, hcfg: HeliosConfig,
                     tcfg: TrainConfig):
    from repro.models import init_params
    params = init_params(key, cfg, _dt(tcfg.param_dtype))
    opt = make_opt(cfg, tcfg)
    api = build(cfg)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.asarray(0, jnp.int32),
            "helios": ST.init_state(api.mask_schema, 1.0, 0)}


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, rt: dict):
    api = build(cfg)

    def prefill_step(params, batch):
        return api.prefill_fn(params, batch, cfg, rt, None)

    return prefill_step


def make_serve_step(cfg: ModelConfig, rt: dict):
    api = build(cfg)

    def serve_step(params, token, cache):
        return api.decode_fn(params, token, cache, cfg, rt, None)

    return serve_step


# ---------------------------------------------------------------------------
# federated round step (multi-pod: pods = FL clients)
# ---------------------------------------------------------------------------


def make_fl_round_step(cfg: ModelConfig, hcfg: HeliosConfig,
                       tcfg: TrainConfig, rt: dict, num_clients: int):
    """One FL round fused into a single compiled program.

    state["params"]/["opt"]/["helios"] carry a leading client dim (C, ...)
    sharded over "pod"; batch is (C, E, per-client-batch, ...).  Aggregation
    = Eq. 10 alpha-weighted mean over the client dim (one all-reduce across
    pods per round), after which every client restarts from the new global.
    """
    api = build(cfg)
    axes = logical_axes(cfg)
    schema = api.mask_schema
    opt = make_opt(cfg, tcfg)

    def client_round(params, opt_state, helios, cbatch, step):
        masks = helios["masks"] if hcfg.enabled else None

        def one_step(carry, b):
            p, s = carry
            loss, grads = jax.value_and_grad(
                lambda pp: api.loss_fn(pp, b, cfg, rt, masks))(p)
            grads, _ = clip_by_global_norm(grads, tcfg.grad_clip)
            updates, s = opt.update(grads, s, p, step)
            if hcfg.enabled:
                um = MK.expand_masks(axes, masks, updates)
                updates = MK.apply_mask_tree(updates, um)
            return (apply_updates(p, updates), s), loss

        (params, opt_state), losses = jax.lax.scan(
            one_step, (params, opt_state), cbatch)
        return params, opt_state, losses.mean()

    def fl_round_step(state, batch):
        params, opt_state, helios = state["params"], state["opt"], state["helios"]
        new_p, new_o, losses = jax.vmap(
            lambda p, o, h, b: client_round(p, o, h, b, state["step"])
        )(params, opt_state, helios, batch)

        # Eq. 10: alpha_n = r_n / sum r_m from each client's mask fraction
        if hcfg.enabled:
            ratios = jax.vmap(
                lambda h: MK.selected_fraction(h["masks"]))(helios)
        else:
            ratios = jnp.ones((num_clients,), jnp.float32)
        alpha = ratios / jnp.maximum(ratios.sum(), 1e-9)

        agg = jax.tree.map(
            lambda t: jnp.tensordot(alpha.astype(jnp.float32),
                                    t.astype(jnp.float32), axes=1
                                    ).astype(t.dtype), new_p)
        # every client restarts from the new global model
        bcast = jax.tree.map(
            lambda g: jnp.broadcast_to(g[None], (num_clients,) + g.shape), agg)
        new_state = {"params": bcast, "opt": new_o,
                     "step": state["step"] + jnp.asarray(1, jnp.int32),
                     "helios": helios}
        return new_state, {"loss": losses.mean(), "alpha": alpha}

    return fl_round_step


def abstract_fl_state(cfg: ModelConfig, hcfg: HeliosConfig, tcfg: TrainConfig,
                      num_clients: int):
    base = abstract_train_state(cfg, hcfg, tcfg)

    def stackify(tree):
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((num_clients,) + l.shape, l.dtype),
            tree)

    return {"params": stackify(base["params"]), "opt": stackify(base["opt"]),
            "step": base["step"], "helios": stackify(base["helios"])}


def fl_state_shardings(cfg: ModelConfig, state_abs, mesh):
    """Client dim -> 'pod'; inner dims follow the usual rules."""
    axes = logical_axes(cfg)
    stacked_axes = jax.tree.map(
        lambda a: ("clients",) + a, axes,
        is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(e, (str, type(None))) for e in x))
    rules = dict(SH.rules_for(cfg))
    rules["clients"] = ("pod",)
    pshard = SH.param_shardings(stacked_axes, state_abs["params"], mesh,
                                rules)
    if isinstance(state_abs["opt"], dict) and \
            set(state_abs["opt"]) <= {"m", "v"}:
        opt_shard = {k: pshard for k in state_abs["opt"]}
    else:
        opt_shard = SH.replicated(state_abs["opt"], mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    helios_shard = jax.tree.map(
        lambda l: NamedSharding(mesh, P(*(("pod",) + (None,) * (l.ndim - 1)))
                                if l.ndim >= 1 and l.shape[0] ==
                                jax.tree.leaves(state_abs["params"])[0].shape[0]
                                else P()),
        state_abs["helios"])
    return {"params": pshard, "opt": opt_shard,
            "step": SH.replicated(state_abs["step"], mesh),
            "helios": helios_shard}


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig, tcfg: TrainConfig):
    return input_specs(cfg, shape, embed_dtype=_dt(tcfg.compute_dtype))


def fl_abstract_batch(cfg: ModelConfig, shape: ShapeConfig, tcfg: TrainConfig,
                      num_clients: int, local_steps: int):
    base = input_specs(cfg, shape, embed_dtype=_dt(tcfg.compute_dtype))

    def stackify(l):
        per_client = l.shape[0] // num_clients
        return jax.ShapeDtypeStruct(
            (num_clients, local_steps, per_client) + l.shape[1:], l.dtype)

    return jax.tree.map(stackify, base)
