"""Runtime contract guards for the federated engines (layer 2 of
repro.analysis).

The static linter (repro.analysis.lint) catches JAX hazards it can see in
the source; this module catches the ones only visible at runtime:

* **transfer guard** — :func:`no_host_transfers` forbids implicit
  device->host conversions (``float()``, ``np.asarray``, ``.item()``, …)
  inside the engines' hot loops.  Intended syncs are whitelisted with
  :func:`expected_transfer`.  Implemented by patching the concrete jax
  array class's host-conversion hooks, because
  ``jax.transfer_guard_device_to_host`` is inert on the CPU backend (both
  live on the same memory space, so XLA never issues a "transfer").
* **NaN/Inf tripwires** — :func:`assert_finite`, a checkify-backed
  finiteness check over a pytree's inexact leaves (aggregation outputs,
  post-round globals).
* **compile budgets** — :func:`check_compile_budget` asserts every engine
  seam holds at most ONE compiled program per shape signature (the
  invariant previously duplicated as ad-hoc ``_cache_size()`` asserts in
  tests/test_sharded_engine.py and tests/test_async_engine.py).
* **domain invariants** — Eq. 2 masks 0/1 and block-constant at
  ``mask_block`` granularity with a selected ratio ~ P
  (:func:`check_mask_invariants`), staleness weights in (0, 1] and
  monotone (:func:`check_staleness`), and the snapshot ring never evicting
  a live anchor (:func:`check_ring` / :func:`check_snapshot_bound`).
* **@contract** — a decorator attaching pre/post checks at library seams
  (soft_train.begin_cycle, aggregation.*, selection.select_masks,
  kernels.ops.*).  Checkers skip traced values, so decorated functions
  stay jit/vmap/shard_map-safe.

Everything compiles out under ``REPRO_CONTRACTS=off`` (the default): each
guard is a single cheap boolean test and the array-class patch is never
installed, so benchmarks measure the real engines.  Enable with
``REPRO_CONTRACTS=on`` or in-process via :func:`override`.
"""
from __future__ import annotations

import contextlib
import functools
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np


class ContractError(AssertionError):
    """A runtime contract was violated (raised only with contracts on)."""


_TLS = threading.local()

#: cheap monotone counters, exported into BENCH_*.json by the benchmark
#: harness; only written when contracts are enabled
counters = {
    "guarded_sections": 0,
    "expected_transfers": 0,
    "blocked_transfers": 0,
    "finite_checks": 0,
    "mask_checks": 0,
    "staleness_checks": 0,
    "ring_checks": 0,
    "compile_checks": 0,
}


def reset_counters() -> dict:
    """Zero all counters; returns the dict (benches snapshot per phase)."""
    for k in counters:
        counters[k] = 0
    return counters


def _env_enabled() -> bool:
    return os.environ.get("REPRO_CONTRACTS", "off").strip().lower() in (
        "on", "1", "true", "yes")


def enabled() -> bool:
    """Contracts on?  A session :func:`override` beats ``REPRO_CONTRACTS``."""
    ov = getattr(_TLS, "override", None)
    return _env_enabled() if ov is None else ov


@contextlib.contextmanager
def override(value: bool):
    """Force contracts on/off for a scope (tests/benches flip in-process)."""
    prev = getattr(_TLS, "override", None)
    _TLS.override = bool(value)
    try:
        yield
    finally:
        _TLS.override = prev


def has_tracers(*trees) -> bool:
    """True when any leaf of any pytree is a jax tracer (checkers bail:
    value-level contracts only run on concrete arrays)."""
    return any(isinstance(x, jax.core.Tracer)
               for t in trees for x in jax.tree.leaves(t))


# ---------------------------------------------------------------------------
# transfer guard
# ---------------------------------------------------------------------------

_GUARD_INSTALLED = False
#: host-conversion hooks of the concrete array class; each is an implicit
#: device->host sync when called on a device array
_HOST_HOOKS = ("__array__", "__float__", "__int__", "__bool__",
               "__complex__", "item", "tolist")


def _guard_depth() -> int:
    return getattr(_TLS, "guard_depth", 0)


def _allow_depth() -> int:
    return getattr(_TLS, "allow_depth", 0)


def _install_guard() -> None:
    """Patch the concrete jax array class so host-conversion hooks raise
    inside guarded sections.  Installed lazily on the FIRST enabled guard
    (a process that never enables contracts never pays the indirection);
    jit tracing/lowering never calls these hooks — device closure constants
    are consumed through the C++ dispatch path — so the guard can stay
    active across warm-up compiles without false positives."""
    global _GUARD_INSTALLED
    if _GUARD_INSTALLED:
        return
    array_cls = type(jnp.zeros(()))

    def _wrap(name, orig):
        def hook(self, *args, **kwargs):
            if _guard_depth() > 0 and _allow_depth() == 0 and enabled():
                counters["blocked_transfers"] += 1
                tag = getattr(_TLS, "guard_tag", "?")
                raise ContractError(
                    f"implicit device->host transfer ({name}) inside "
                    f"guarded section {tag!r}; wrap intended syncs in "
                    "contracts.expected_transfer(...)")
            return orig(self, *args, **kwargs)
        hook.__name__ = name
        return hook

    for name in _HOST_HOOKS:
        orig = getattr(array_cls, name, None)
        if orig is not None:
            setattr(array_cls, name, _wrap(name, orig))

    # numpy converts jax arrays through the C-level buffer protocol, never
    # touching the Python dunders above — wrap the numpy entry points too
    # (passthrough unless the operand is a device array in a guarded
    # section; callers that froze ``from numpy import asarray`` before the
    # first enabled guard are the static linter's (R3) territory)
    def _np_wrap(fname, orig):
        @functools.wraps(orig)
        def hook(obj, *args, **kwargs):
            if isinstance(obj, array_cls) and _guard_depth() > 0 and \
                    _allow_depth() == 0 and enabled():
                counters["blocked_transfers"] += 1
                tag = getattr(_TLS, "guard_tag", "?")
                raise ContractError(
                    f"implicit device->host transfer (numpy.{fname}) "
                    f"inside guarded section {tag!r}; wrap intended syncs "
                    "in contracts.expected_transfer(...)")
            return orig(obj, *args, **kwargs)
        return hook

    for fname in ("asarray", "array"):
        setattr(np, fname, _np_wrap(fname, getattr(np, fname)))
    _GUARD_INSTALLED = True


@contextlib.contextmanager
def no_host_transfers(tag: str):
    """Forbid implicit device->host conversions while the block runs.

    Engine hot loops (run_sync's train step, run_async's bucket step) wrap
    themselves in this; anything that silently pulls a device array to host
    inside — ``float(loss)``, ``np.asarray(ratios)``, ``if device_scalar:``
    — raises :class:`ContractError` instead of hiding a sync."""
    if not enabled():
        yield
        return
    _install_guard()
    counters["guarded_sections"] += 1
    prev_tag = getattr(_TLS, "guard_tag", None)
    _TLS.guard_tag = tag
    _TLS.guard_depth = _guard_depth() + 1
    try:
        yield
    finally:
        _TLS.guard_depth -= 1
        _TLS.guard_tag = prev_tag


@contextlib.contextmanager
def expected_transfer(tag: str):
    """Mark an INTENDED device->host sync inside a guarded section (eval
    metrics, host-resident population scatters, the contract checkers'
    own materializations)."""
    if not enabled() or _guard_depth() == 0:
        yield
        return
    counters["expected_transfers"] += 1
    _TLS.allow_depth = _allow_depth() + 1
    try:
        yield
    finally:
        _TLS.allow_depth -= 1


# ---------------------------------------------------------------------------
# checkify-backed NaN/Inf tripwire
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _finite_checker(n_leaves: int):
    from jax.experimental import checkify

    def body(leaves):
        for i in range(n_leaves):
            checkify.check(jnp.all(jnp.isfinite(leaves[i])),
                           "non-finite values in leaf " + str(i))
        return jnp.zeros((), jnp.int32)

    return jax.jit(checkify.checkify(body))


def assert_finite(tree, tag: str = "params") -> None:
    """checkify-backed NaN/Inf tripwire over a pytree's inexact leaves.

    No-op when contracts are off or any leaf is traced (the eager engine
    seams are where poisoned aggregations must be caught)."""
    if not enabled():
        return
    leaves = tuple(x for x in jax.tree.leaves(tree)
                   if hasattr(x, "dtype")
                   and jnp.issubdtype(x.dtype, jnp.inexact))
    if not leaves or has_tracers(leaves):
        return
    counters["finite_checks"] += 1
    err, _ = _finite_checker(len(leaves))(leaves)
    with expected_transfer("contracts.assert_finite[" + tag + "]"):
        try:
            err.throw()
        except ContractError:
            raise
        except Exception as e:
            raise ContractError(f"{tag}: {e}") from e


# ---------------------------------------------------------------------------
# domain invariants
# ---------------------------------------------------------------------------


def check_mask_invariants(masks, volume=None, block: int = 0, *,
                          tag: str = "masks", slack: int = 1) -> None:
    """Eq. 2 mask contract: 0/1-valued, block-constant at ``block``
    granularity (for unit types wide enough to pool, n >= 4*block, matching
    core.selection.select_masks), and — when ``volume`` is given — a
    selected count per row within ``slack`` blocks/units of
    ``clip(round(P * n), 1, n)``.

    ``masks``: {unit type: (..., L, n)} float arrays (leading client axes
    allowed).  Pass ``volume=None`` to check structure only (post-run
    state sweeps, where the stored volume has drifted past the volume the
    last selection used)."""
    if not enabled() or has_tracers(masks, volume):
        return
    counters["mask_checks"] += 1
    with expected_transfer("contracts.check_mask_invariants[" + tag + "]"):
        vol = None if volume is None else float(np.asarray(volume))
        for key in sorted(masks):
            m = np.asarray(masks[key], np.float32)
            if not np.all((m == 0.0) | (m == 1.0)):
                raise ContractError(
                    f"{tag}/{key}: mask values outside {{0, 1}}")
            n = m.shape[-1]
            rows = m.reshape(-1, n)
            if block and n >= 4 * block:
                nb = -(-n // block)
                pad = nb * block - n
                # edge-padding keeps the ragged tail block's constancy
                # check honest: the pad repeats the last REAL value
                mp = np.pad(rows, ((0, 0), (0, pad)), mode="edge")
                grouped = mp.reshape(rows.shape[0], nb, block)
                if not np.all(grouped == grouped[..., :1]):
                    raise ContractError(
                        f"{tag}/{key}: mask not block-constant at "
                        f"mask_block={block}")
                counts = grouped[..., 0].sum(-1)
                total = nb
            else:
                counts = rows.sum(-1)
                total = n
            if vol is not None:
                exp = np.clip(np.round(np.float32(vol) * total), 1, total)
                if np.any(np.abs(counts - exp) > slack):
                    raise ContractError(
                        f"{tag}/{key}: selected counts {counts.tolist()} "
                        f"vs expected ~{int(exp)} of {total} "
                        f"(P={vol:.4f}, slack={slack})")


def check_staleness(stales, weights=None, a: float = 0.5, *,
                    tag: str = "staleness") -> None:
    """AFO staleness contract: staleness >= 0; the polynomial discounts
    (s + 1)^-a lie in (0, 1] and are monotone non-increasing in s; when
    the traced program's ``weights`` are passed they must match the host
    formula."""
    if not enabled() or has_tracers(stales, weights):
        return
    counters["staleness_checks"] += 1
    with expected_transfer("contracts.check_staleness[" + tag + "]"):
        s = np.asarray(stales, np.float64).reshape(-1)
        if s.size == 0:
            return
        if np.any(s < 0):
            raise ContractError(f"{tag}: negative staleness {s.min()}")
        w = (s + 1.0) ** (-a)
        if np.any(w <= 0.0) or np.any(w > 1.0 + 1e-9):
            raise ContractError(f"{tag}: weights outside (0, 1]")
        order = np.argsort(s)
        if np.any(np.diff(w[order]) > 1e-9):
            raise ContractError(
                f"{tag}: staleness weights not monotone non-increasing")
        if weights is not None:
            wg = np.asarray(weights, np.float64).reshape(-1)[:s.size]
            if np.any(np.abs(wg - w) > 1e-5):
                raise ContractError(
                    f"{tag}: traced weights diverge from (s+1)^-{a}")


def check_ring(ring_or_alloc, n_clients=None, *,
               tag: str = "snapshot-ring") -> None:
    """Snapshot-ring contract: no anchored snapshot was ever evicted, and
    live anchors stay within the ring's data slots (and the client count —
    each client anchors at most one snapshot)."""
    if not enabled():
        return
    counters["ring_checks"] += 1
    alloc = getattr(ring_or_alloc, "alloc", ring_or_alloc)
    if alloc.anchor_misses:
        raise ContractError(
            f"{tag}: {alloc.anchor_misses} anchored snapshots were evicted")
    live = alloc.live_slots()
    if live > alloc.slots - 1:
        raise ContractError(
            f"{tag}: {live} live anchors exceed {alloc.slots - 1} data slots")
    if n_clients is not None and live > n_clients:
        raise ContractError(
            f"{tag}: {live} live anchors for {n_clients} clients")
    if alloc.peak_live > alloc.slots - 1:
        raise ContractError(
            f"{tag}: peak live {alloc.peak_live} exceeded the ring")


def check_snapshot_bound(peak: int, anchor_misses: int, cap: int,
                         n_clients: int, *, tag: str = "snapshots") -> None:
    """Dict-snapshot contract (sequential async loop): anchors are never
    evicted and the store stays bounded by cap + live anchors."""
    if not enabled():
        return
    counters["ring_checks"] += 1
    if anchor_misses:
        raise ContractError(
            f"{tag}: {anchor_misses} anchored snapshots were evicted")
    if peak > cap + n_clients + 1:
        raise ContractError(
            f"{tag}: snapshot peak {peak} exceeds cap {cap} + "
            f"{n_clients} anchors")


# ---------------------------------------------------------------------------
# compile budgets
# ---------------------------------------------------------------------------


def compile_report(run) -> dict:
    """Compiled-program census for an engine: jit cache size per seam.

    Keys: ``local_train`` / ``eval_chunk`` (int), ``round`` (per
    shape-signature dict over the LRU program cache — covers the batched
    AND sharded round programs), ``bucket`` (per padded-bucket-size dict).
    Written into BENCH_*.json by the benchmark harness."""
    rep = {}
    for name in ("_local_train", "_eval_chunk"):
        fn = getattr(run, name, None)
        if fn is not None and hasattr(fn, "_cache_size"):
            rep[name.lstrip("_")] = fn._cache_size()
    cache = getattr(run, "_round_cache", None)
    if cache:
        rep["round"] = {repr(k): fn._cache_size() for k, fn in cache.items()
                        if hasattr(fn, "_cache_size")}
    bcache = getattr(run, "_bucket_cache", None)
    if bcache:
        rep["bucket"] = {int(k): fn._cache_size()
                         for k, fn in bcache.items()}
    return rep


def emit_obs(run, rec) -> None:
    """Bridge into the telemetry recorder (repro.obs): one ``compile``
    event per seam from :func:`compile_report` (so retraces are visible in
    the run log, not just at the budget wall) plus the contract counters
    funneled in as ``contracts.*`` recorder counters.  ``rec`` is duck
    typed — contracts stays import-free of the obs package."""
    for seam, census in compile_report(run).items():
        rec.event("compile", seam=seam, programs=census)
    for k, v in counters.items():
        rec.set("contracts." + k, v)


def check_compile_budget(run, *, max_per_signature: int = 1,
                         max_eval_programs: int = 2,
                         tag: str = "compile") -> None:
    """One compiled program per engine per shape signature.

    Round programs (one per (n_s, n_c) / sharded kpad key) and bucket
    programs (one per padded bucket size) must each hold exactly one
    compiled executable however many cohorts/buckets were drawn; the
    shared local-train step likewise.  ``eval_chunk`` is allowed
    ``max_eval_programs`` (full chunk + the ragged tail chunk)."""
    if not enabled():
        return
    counters["compile_checks"] += 1
    rep = compile_report(run)
    over = []
    if rep.get("local_train", 0) > max_per_signature:
        over.append(f"local_train={rep['local_train']}")
    if rep.get("eval_chunk", 0) > max_eval_programs:
        over.append(f"eval_chunk={rep['eval_chunk']}")
    for key, n in rep.get("round", {}).items():
        if n > max_per_signature:
            over.append(f"round[{key}]={n}")
    for key, n in rep.get("bucket", {}).items():
        if n > max_per_signature:
            over.append(f"bucket[{key}]={n}")
    if over:
        raise ContractError(
            f"{tag}: compile budget exceeded (max {max_per_signature} "
            f"program per signature): " + ", ".join(over))


# ---------------------------------------------------------------------------
# the @contract decorator
# ---------------------------------------------------------------------------


def contract(pre=None, post=None):
    """Attach contract checks to a library seam.

    ``pre(*args, **kwargs)`` runs before the call, ``post(out, *args,
    **kwargs)`` after.  With contracts off the wrapper is one boolean
    test; checkers must tolerate traced inputs (shape-level checks may
    run under jit, value-level checks should bail via
    :func:`has_tracers`)."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not enabled():
                return fn(*args, **kwargs)
            if pre is not None:
                pre(*args, **kwargs)
            out = fn(*args, **kwargs)
            if post is not None:
                post(out, *args, **kwargs)
            return out
        wrapper.__wrapped__ = fn
        return wrapper
    return deco
