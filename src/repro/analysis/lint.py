"""Driver for the repro.analysis static linter (layer 1).

Walks the given paths, parses each ``.py`` file once, runs every
per-file rule (R1-R5 + R6's unused-import check) plus the project rule
(R6 orphan modules), applies ``# repro: noqa[Rn]`` suppressions, and
returns findings / a machine-readable JSON report.

noqa semantics: ``# repro: noqa[R3]`` on the finding's line suppresses
that rule there; a rule list (``noqa[R2,R3]``) or ``noqa[*]`` works too.
Module-level findings (line 1, e.g. R6 orphans) accept the comment
anywhere in the file's first 10 lines.  Suppressed findings stay in the
JSON report (``suppressed: true``) so intentional exceptions remain
visible; the ``lint`` CLI exits non-zero only on unsuppressed ones.
"""
from __future__ import annotations

import ast
import json
import os
import re
from typing import Dict, List, Optional, Sequence

from repro.analysis.rules import ALL_RULES, Finding, ModuleInfo, ProjectRule

NOQA_RE = re.compile(r"#\s*repro:\s*noqa\[([A-Za-z0-9*,\s]+)\]")


def _iter_py_files(paths: Sequence[str]) -> List[str]:
    files: List[str] = []
    for p in paths:
        p = os.path.abspath(p)
        if os.path.isfile(p):
            files.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            files.extend(os.path.join(dirpath, f) for f in filenames
                         if f.endswith(".py"))
    return sorted(set(files))


def _noqa_lines(source: str) -> Dict[int, set]:
    """line number -> set of suppressed rule ids ('*' = all)."""
    out: Dict[int, set] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = NOQA_RE.search(line)
        if m:
            rules = {r.strip().upper() for r in m.group(1).split(",")
                     if r.strip()}
            out[i] = rules
    return out


def _apply_noqa(findings: List[Finding],
                noqa_by_path: Dict[str, Dict[int, set]]) -> None:
    for f in findings:
        noqa = noqa_by_path.get(f.path, {})
        lines = [f.line]
        if f.line == 1:                     # module-level finding
            lines = list(range(1, 11))
        for ln in lines:
            rules = noqa.get(ln)
            if rules and ("*" in rules or f.rule in rules):
                f.suppressed = True
                break


def find_repo_root(files: Sequence[str]) -> Optional[str]:
    """Nearest ancestor of a linted file that contains ``src/repro``."""
    for f in files:
        cur = os.path.dirname(os.path.abspath(f))
        while cur != os.path.dirname(cur):
            if os.path.isdir(os.path.join(cur, "src", "repro")):
                return cur
            cur = os.path.dirname(cur)
    return None


def lint_paths(paths: Sequence[str],
               rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint every .py under ``paths``; returns ALL findings (check
    ``.suppressed`` or use :func:`unsuppressed`)."""
    files = _iter_py_files(paths)
    selected = [r for r in ALL_RULES
                if rules is None or r.id in set(rules)]
    modules: List[ModuleInfo] = []
    findings: List[Finding] = []
    noqa_by_path: Dict[str, Dict[int, set]] = {}
    for path in files:
        try:
            with open(path, encoding="utf-8") as fh:
                source = fh.read()
            tree = ast.parse(source)
        except (OSError, SyntaxError) as e:
            findings.append(Finding("E0", path, getattr(e, "lineno", 1) or 1,
                                    0, f"could not parse: {e}"))
            continue
        mi = ModuleInfo(path, source, tree)
        modules.append(mi)
        noqa_by_path[path] = _noqa_lines(source)
        for rule in selected:
            findings.extend(rule.check(mi))
    repo_root = find_repo_root(files)
    for rule in selected:
        if isinstance(rule, ProjectRule):
            findings.extend(rule.check_project(modules, repo_root))
    _apply_noqa(findings, noqa_by_path)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def unsuppressed(findings: Sequence[Finding]) -> List[Finding]:
    return [f for f in findings if not f.suppressed]


def make_report(findings: Sequence[Finding],
                paths: Sequence[str]) -> dict:
    """Machine-readable lint report (uploaded as a CI artifact)."""
    rel = os.getcwd()
    by_rule: Dict[str, int] = {}
    for f in findings:
        if not f.suppressed:
            by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    return {
        "tool": "repro.analysis",
        "paths": list(paths),
        "total": len(findings),
        "unsuppressed": len(unsuppressed(findings)),
        "by_rule": dict(sorted(by_rule.items())),
        "findings": [
            {**f.to_json(), "path": os.path.relpath(f.path, rel)}
            for f in findings],
    }


def write_report(report: dict, out_path: str) -> None:
    with open(out_path, "w", encoding="utf-8") as fh:
        json.dump(report, fh, indent=2, sort_keys=False)
        fh.write("\n")
