"""CLI for the repro.analysis linter.

  python -m repro.analysis lint src            # exit 1 on new findings
  python -m repro.analysis report src tests benchmarks --out lint.json
  python -m repro.analysis lint src --rules R3,R6

``lint`` prints findings and fails on unsuppressed ones (suppressed ones
print with a ``(noqa)`` marker under ``--verbose``); ``report`` always
exits 0 and emits the full JSON report (CI uploads it as an artifact).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import lint


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("command", choices=["lint", "report"])
    ap.add_argument("paths", nargs="+", help="files / directories to lint")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids (default: all)")
    ap.add_argument("--out", default=None,
                    help="write the JSON report to this file")
    ap.add_argument("--verbose", action="store_true",
                    help="also print suppressed (noqa) findings")
    args = ap.parse_args(argv)

    rules = [r.strip().upper() for r in args.rules.split(",")] \
        if args.rules else None
    findings = lint.lint_paths(args.paths, rules=rules)
    report = lint.make_report(findings, args.paths)
    if args.out:
        lint.write_report(report, args.out)

    if args.command == "report":
        if not args.out:
            json.dump(report, sys.stdout, indent=2)
            print()
        else:
            print(f"wrote {args.out}: {report['total']} findings "
                  f"({report['unsuppressed']} unsuppressed)")
        return 0

    shown = findings if args.verbose else lint.unsuppressed(findings)
    for f in shown:
        print(f)
    bad = lint.unsuppressed(findings)
    n_noqa = len(findings) - len(bad)
    print(f"repro.analysis: {len(bad)} finding(s), "
          f"{n_noqa} suppressed via noqa")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
