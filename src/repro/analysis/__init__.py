"""repro.analysis — JAX-hazard linter + runtime contract guards.

Layer 1 (static): an AST linter with Helios-specific rules —

  R1  Python branching on traced values inside jitted functions
  R2  jax.random key reuse / missing split along a dataflow path
  R3  host-sync hazards (float/.item()/np.asarray) inside hot loops
  R4  retrace hazards (per-call jit, jit-in-loop, unhashable statics)
  R5  donated-buffer use-after-donate
  R6  dead code (unused imports, orphan modules)

CLI: ``python -m repro.analysis lint|report <paths>``; suppress a finding
with ``# repro: noqa[Rn]`` on its line.

Layer 2 (runtime): :mod:`repro.analysis.contracts` — transfer guards,
checkify NaN tripwires, compile-count budgets, and domain invariants at
the engine seams, all gated by ``REPRO_CONTRACTS`` (off by default).
"""
from repro.analysis import contracts
from repro.analysis.lint import (lint_paths, make_report, unsuppressed,
                                 write_report)
from repro.analysis.rules import ALL_RULES, Finding

__all__ = ["ALL_RULES", "Finding", "contracts", "lint_paths", "make_report",
           "unsuppressed", "write_report"]
