"""R3 — host-sync hazards inside round/bucket loops.

``float(x)``, ``int(x)``, ``np.asarray(x)``, ``x.item()``, ``x.tolist()``
on a device array block the host on the device stream.  Outside a loop
that is a deliberate sync point; inside the engines' per-round /
per-event loops it serializes dispatch against execution and silently
destroys pipelining.  Device results consumed by host bookkeeping should
be converted once, after the loop (or behind the eval gate), and
intended in-loop syncs (metrics) marked ``# repro: noqa[R3]``.

Device-ness is a name-level taint: ``jax.*`` calls, calls through
``jax.jit``-bound names, and private ``self._*`` engine methods seed the
taint; assignments propagate it.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.rules import base

#: builtins/numpy entry points that force a device->host sync when handed
#: a device array
SYNC_BUILTINS = {"float", "int", "bool"}
SYNC_NUMPY = {"numpy.asarray", "numpy.array", "numpy.float32",
              "numpy.float64", "numpy.int32", "numpy.int64"}
SYNC_METHODS = {"item", "tolist"}


class HostSyncRule(base.Rule):
    id = "R3"
    name = "host-sync-in-loop"

    def check(self, mi: base.ModuleInfo) -> List[base.Finding]:
        out: List[base.Finding] = []
        fns = [n for n in ast.walk(mi.tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        traced = mi.traced_functions()
        for fn in fns:
            if fn in traced:
                continue                    # R1's territory
            taint = base.device_tainted_names(mi, fn)
            if not taint:
                continue
            for loop in ast.walk(fn):
                if not isinstance(loop, (ast.For, ast.AsyncFor, ast.While)):
                    continue
                for node in ast.walk(loop):
                    hit = self._sync_call(mi, node, taint)
                    if hit:
                        out.append(self.finding(mi, node, hit))
        return out

    def _sync_call(self, mi, node, taint) -> str:
        if not isinstance(node, ast.Call):
            return ""
        path = mi.resolve(node.func)
        if isinstance(node.func, ast.Name) and \
                node.func.id in SYNC_BUILTINS and len(node.args) == 1:
            if base.expr_uses_device_value(mi, node.args[0], taint):
                return (f"{node.func.id}() on a device value inside a "
                        "loop — implicit device->host sync per iteration; "
                        "convert once after the loop")
        if path in SYNC_NUMPY and node.args:
            if base.expr_uses_device_value(mi, node.args[0], taint):
                return (f"{path}() on a device value inside a loop — "
                        "implicit device->host copy per iteration")
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in SYNC_METHODS and not node.args:
            if base.expr_uses_device_value(mi, node.func.value, taint):
                return (f".{node.func.attr}() on a device value inside a "
                        "loop — implicit device->host sync per iteration")
        return ""
