"""R4 — retrace hazards: per-call jit wrapping and unhashable statics.

Three shapes of the same bug (every call compiles a fresh program):

* ``jax.jit(f)(x)`` — the jitted callable is created and discarded per
  call, so its compile cache dies with it;
* ``jax.jit(...)`` inside a ``for``/``while`` body — a new callable (and
  cache) per iteration;
* a jit with ``static_argnums``/``static_argnames`` called with an
  unhashable literal (list/dict/set) in a static position — TypeError at
  best, retrace-per-value at worst when the caller "fixes" it by tupling
  a fresh object each call.

Factory methods that memoize the jitted callable (the engines'
``_get_cached_program`` / ``_get_bucket_fn``) are the sanctioned pattern
and do not trip this rule.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.rules import base

JIT_WRAPPERS = {"jax.jit", "jax.pmap"}
MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                    ast.SetComp, ast.GeneratorExp)


class RetraceRule(base.Rule):
    id = "R4"
    name = "retrace"

    def check(self, mi: base.ModuleInfo) -> List[base.Finding]:
        out: List[base.Finding] = []
        static_of: Dict[str, Set[int]] = {}
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Call):
                continue
            path = mi.resolve(node.func)
            if path in JIT_WRAPPERS:
                parent = getattr(node, "_repro_parent", None)
                if isinstance(parent, ast.Call) and parent.func is node:
                    out.append(self.finding(
                        mi, node,
                        f"{path}(f)(...) creates and discards a fresh "
                        "compiled callable per call — hoist the jit out"))
                loop = self._enclosing_loop(node)
                if loop is not None:
                    out.append(self.finding(
                        mi, node,
                        f"{path}(...) inside a loop — a new callable "
                        "(and compile cache) per iteration; build once "
                        "outside or memoize by signature"))
                self._record_static(mi, node, static_of)
        # unhashable literals at static positions of jit-bound names
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Call) or \
                    not isinstance(node.func, ast.Name):
                continue
            statics = static_of.get(node.func.id)
            if not statics:
                continue
            for i, arg in enumerate(node.args):
                if i in statics and isinstance(arg, MUTABLE_LITERALS):
                    out.append(self.finding(
                        mi, arg,
                        f"unhashable literal passed in static position "
                        f"{i} of jitted {node.func.id!r} — forces "
                        "TypeError/retrace; pass a hashable (tuple)"))
        return out

    def _enclosing_loop(self, node):
        for p in base.parents(node):
            if isinstance(p, (ast.For, ast.AsyncFor, ast.While)):
                return p
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                return None
        return None

    def _record_static(self, mi, call: ast.Call,
                       static_of: Dict[str, Set[int]]) -> None:
        statics: Set[int] = set()
        for kw in call.keywords:
            if kw.arg == "static_argnums":
                statics |= set(self._int_elts(kw.value))
        if not statics:
            return
        parent = getattr(call, "_repro_parent", None)
        if isinstance(parent, ast.Assign):
            for t in parent.targets:
                if isinstance(t, ast.Name):
                    static_of[t.id] = statics

    def _int_elts(self, node) -> Tuple[int, ...]:
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return (node.value,)
        if isinstance(node, (ast.Tuple, ast.List)):
            return tuple(e.value for e in node.elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, int))
        return ()
