"""R2 — jax.random key reuse / missing split along a dataflow path.

A PRNG key consumed by two samplers yields correlated draws; a key
consumed inside a loop without per-iteration re-derivation yields the
SAME draw every iteration.  Keys must be re-derived (``split`` /
``fold_in``) between consumptions — deriving subkeys is not consumption,
so the repo's ``fold_in(key, i)`` streams pass.

The analysis is scope-local and order-based: statements are walked in
source order; branches are merged pessimistically (a consumption on
either side counts).
"""
from __future__ import annotations

import ast
from typing import Dict, List

from repro.analysis.rules import base

#: jax.random functions that DERIVE keys instead of consuming entropy
DERIVERS = {"split", "fold_in", "PRNGKey", "key", "key_data",
            "wrap_key_data", "clone"}
#: calls whose result is a key (or tuple/array of keys)
KEY_MAKERS = {"jax.random.PRNGKey", "jax.random.key", "jax.random.split",
              "jax.random.fold_in", "jax.random.wrap_key_data",
              "jax.random.clone"}


def _is_sampler(path: str) -> bool:
    return path is not None and path.startswith("jax.random.") and \
        path.rsplit(".", 1)[-1] not in DERIVERS


class KeyReuseRule(base.Rule):
    id = "R2"
    name = "key-reuse"

    def check(self, mi: base.ModuleInfo) -> List[base.Finding]:
        out: List[base.Finding] = []
        scopes = [mi.tree] + [n for n in ast.walk(mi.tree)
                              if isinstance(n, (ast.FunctionDef,
                                                ast.AsyncFunctionDef))]
        for scope in scopes:
            body = scope.body if hasattr(scope, "body") else []
            self._walk(mi, body, {}, loop_assigned=None, out=out)
        return out

    # -- helpers ---------------------------------------------------------
    def _key_vars_assigned(self, mi, stmt) -> List[str]:
        """Names bound to fresh keys by this statement."""
        names: List[str] = []
        if not isinstance(stmt, ast.Assign):
            return names
        value = stmt.value
        is_key = isinstance(value, ast.Call) and \
            mi.resolve(value.func) in KEY_MAKERS
        if isinstance(value, ast.Subscript):    # split(...)[0]
            inner = value.value
            is_key = isinstance(inner, ast.Call) and \
                mi.resolve(inner.func) in KEY_MAKERS
        if not is_key:
            return names
        for t in stmt.targets:
            if isinstance(t, ast.Name):
                names.append(t.id)
            elif isinstance(t, (ast.Tuple, ast.List)):
                names.extend(e.id for e in t.elts
                             if isinstance(e, ast.Name))
        return names

    def _consumptions(self, mi, node) -> List[tuple]:
        """(key name, call node) for each sampler call consuming a key
        variable inside ``node`` (nested defs excluded — own scope)."""
        cons = []

        def visit(sub):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda, ast.ClassDef)):
                return                      # separate scope
            if isinstance(sub, ast.Call) and \
                    _is_sampler(mi.resolve(sub.func)):
                args = list(sub.args)
                for kw in sub.keywords:
                    if kw.arg == "key":
                        args.insert(0, kw.value)
                if args and isinstance(args[0], ast.Name):
                    cons.append((args[0].id, sub))
            for child in ast.iter_child_nodes(sub):
                visit(child)

        for child in ast.iter_child_nodes(node):
            visit(child)
        return cons

    def _terminates(self, stmts) -> bool:
        return bool(stmts) and isinstance(
            stmts[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue))

    def _consume(self, mi, node, consumed, loop_assigned, out) -> None:
        for name, call in self._consumptions(mi, node):
            if name in consumed and consumed[name] >= 1:
                out.append(self.finding(
                    mi, call,
                    f"PRNG key {name!r} consumed again without "
                    "split/fold_in — correlated draws"))
            elif loop_assigned is not None and name not in loop_assigned:
                out.append(self.finding(
                    mi, call,
                    f"PRNG key {name!r} consumed inside a loop without "
                    "per-iteration re-derivation — identical draws "
                    "every iteration"))
            consumed[name] = consumed.get(name, 0) + 1

    def _walk(self, mi, stmts, consumed: Dict[str, int],
              loop_assigned, out: List[base.Finding]) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue                    # separate scope, visited on its own
            if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
                header = stmt.iter if isinstance(
                    stmt, (ast.For, ast.AsyncFor)) else stmt.test
                self._consume(mi, ast.Expr(value=header), consumed,
                              loop_assigned, out)
                assigned = {t.id for s in ast.walk(stmt)
                            for t in getattr(s, "targets", [])
                            if isinstance(t, ast.Name)}
                if isinstance(stmt, (ast.For, ast.AsyncFor)) and \
                        isinstance(stmt.target, ast.Name):
                    assigned.add(stmt.target.id)
                self._walk(mi, stmt.body, consumed, assigned, out)
                self._walk(mi, stmt.orelse, consumed, loop_assigned, out)
                continue
            if isinstance(stmt, ast.If):
                self._consume(mi, ast.Expr(value=stmt.test), consumed,
                              loop_assigned, out)
                a, b = dict(consumed), dict(consumed)
                self._walk(mi, stmt.body, a, loop_assigned, out)
                self._walk(mi, stmt.orelse, b, loop_assigned, out)
                # a branch that returns/raises never rejoins: its
                # consumptions must not poison the fall-through path
                merge = []
                if not self._terminates(stmt.body):
                    merge.append(a)
                if not self._terminates(stmt.orelse):
                    merge.append(b)
                if merge:
                    for k in {k for m in merge for k in m}:
                        consumed[k] = max(m.get(k, 0) for m in merge)
                continue
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self._consume(mi, ast.Expr(value=item.context_expr),
                                  consumed, loop_assigned, out)
                self._walk(mi, stmt.body, consumed, loop_assigned, out)
                continue
            if isinstance(stmt, ast.Try):
                self._walk(mi, stmt.body, consumed, loop_assigned, out)
                for h in stmt.handlers:
                    self._walk(mi, h.body, consumed, loop_assigned, out)
                self._walk(mi, stmt.finalbody, consumed, loop_assigned, out)
                continue
            self._consume(mi, stmt, consumed, loop_assigned, out)
            for name in self._key_vars_assigned(mi, stmt):
                consumed[name] = 0
                if loop_assigned is not None:
                    loop_assigned.add(name)
