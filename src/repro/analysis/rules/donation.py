"""R5 — donated-buffer use-after-donate.

``jax.jit(f, donate_argnums=...)`` lets XLA reuse the donated argument's
buffer for the output; after the call the donated array is DELETED and
any later read raises (or, on some backends, silently reads garbage).
The async engine's snapshot ring donates the globals + the whole ring
every bucket — the sanctioned pattern reassigns the donated names in the
same statement (``g, ring, _ = fn(g, ring, ...)``), which this rule
recognizes as safe.

Flagged: a name/attribute donated to a jit-bound callable and then read
again in the same scope before being reassigned, and the same expression
donated twice in one call (aliased donation).

Scope: direct-name bindings only (``fn = jax.jit(..., donate_argnums=)``
then ``fn(...)`` in the same file); donations routed through containers
or factory returns need the runtime transfer/compile contracts instead.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis.rules import base

JIT_WRAPPERS = {"jax.jit", "jax.pmap"}


def _expr_key(node) -> str:
    """Stable identity for a Name/Attribute chain (``ring.params``) —
    ctx-insensitive, so a Load of ``g`` matches the Store that
    reassigned it."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        return ".".join([node.id] + list(reversed(parts)))
    return ""


class DonationRule(base.Rule):
    id = "R5"
    name = "use-after-donate"

    def check(self, mi: base.ModuleInfo) -> List[base.Finding]:
        out: List[base.Finding] = []
        donating: Dict[str, Set[int]] = {}
        for node in ast.walk(mi.tree):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    mi.resolve(node.value.func) in JIT_WRAPPERS:
                nums: Set[int] = set()
                for kw in node.value.keywords:
                    if kw.arg == "donate_argnums":
                        nums |= self._ints(kw.value)
                if nums:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            donating[t.id] = nums
        if not donating:
            return out
        for scope in [mi.tree] + [n for n in ast.walk(mi.tree)
                                  if isinstance(n, (ast.FunctionDef,
                                                    ast.AsyncFunctionDef))]:
            self._check_scope(mi, scope, donating, out)
        return out

    def _ints(self, node) -> Set[int]:
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return {node.value}
        if isinstance(node, (ast.Tuple, ast.List)):
            return {e.value for e in node.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, int)}
        return set()

    def _check_scope(self, mi, scope, donating, out) -> None:
        stmts = [s for s in ast.walk(scope)
                 if isinstance(s, ast.stmt) and self._owner(s) is scope]
        stmts.sort(key=lambda s: (s.lineno, s.col_offset))
        for si, stmt in enumerate(stmts):
            # only calls whose innermost owning statement is ``stmt``: a
            # call in a loop body belongs to the body statement (whose
            # targets decide reassignment), not to the enclosing loop
            for call in self._own_calls(stmt):
                if not isinstance(call.func, ast.Name) or \
                        call.func.id not in donating:
                    continue
                donated = []                # (key, arg node)
                for i in sorted(donating[call.func.id]):
                    if i < len(call.args):
                        k = _expr_key(call.args[i])
                        if k:
                            if any(k == kk for kk, _ in donated):
                                out.append(self.finding(
                                    mi, call.args[i],
                                    "same buffer donated twice in one "
                                    "call — aliased donation"))
                            donated.append((k, call.args[i]))
                targets = self._stmt_targets(stmt)
                for k, arg in donated:
                    if k in targets:
                        continue            # reassigned by the same stmt
                    use = self._later_read(stmts[si + 1:], k)
                    if use is not None:
                        out.append(self.finding(
                            mi, use,
                            f"donated argument {ast.unparse(arg)!r} read "
                            "after donation — the buffer no longer "
                            "exists; reassign it from the call's output"))
        return

    def _own_calls(self, stmt) -> List[ast.Call]:
        """Calls in ``stmt`` not nested inside a child statement."""
        out: List[ast.Call] = []

        def visit(node, top=False):
            if not top and isinstance(node, ast.stmt):
                return
            if isinstance(node, ast.Call):
                out.append(node)
            for child in ast.iter_child_nodes(node):
                visit(child)

        visit(stmt, top=True)
        return out

    def _owner(self, node):
        for p in base.parents(node):
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda, ast.Module)):
                return p
        return None

    def _stmt_targets(self, stmt) -> Set[str]:
        targets: Set[str] = set()
        tnodes = []
        if isinstance(stmt, ast.Assign):
            tnodes = stmt.targets
        elif isinstance(stmt, ast.AugAssign):
            tnodes = [stmt.target]
        for t in tnodes:
            if isinstance(t, (ast.Tuple, ast.List)):
                for e in t.elts:
                    k = _expr_key(e)
                    if k:
                        targets.add(k)
            else:
                k = _expr_key(t)
                if k:
                    targets.add(k)
        return targets

    def _later_read(self, stmts, key):
        """First Load of ``key`` in later statements before a reassign."""
        for stmt in stmts:
            if key in self._stmt_targets(stmt):
                # reassigned: reads inside the SAME statement's value are
                # fine only if they are the assignment source — treat a
                # read in the value as a use-after-donate first
                for sub in ast.walk(stmt.value) \
                        if isinstance(stmt, ast.Assign) else []:
                    if _expr_key(sub) == key:
                        return sub
                return None
            for sub in ast.walk(stmt):
                if _expr_key(sub) == key and \
                        isinstance(getattr(sub, "ctx", None), ast.Load):
                    return sub
        return None
