"""R6 — dead code: unused imports (per file) + orphan modules (project).

**Unused imports**: an imported binding never referenced in the file (a
name load, an attribute root, or an ``__all__`` string).  ``__init__.py``
files are exempt (re-export surface).

**Orphan modules**: a ``src/repro`` module unreachable from the repo's
executable surface.  Liveness roots are

* every module under ``examples/`` and ``benchmarks/``, and
* every module named by a ``-m repro.x.y`` execution string or a bare
  ``"repro.x.y"`` string literal (e.g. a subprocess argv element)
  anywhere in the repo's .py files or CI workflows — a module's own
  docstring/comments do not keep it alive;

liveness propagates through name-level imports, with ``from package
import name`` resolved through the package ``__init__``'s re-export
table to the defining submodule.  A package ``__init__`` import only
counts as an edge when the bound name is actually *used* in the init
body — a pure re-export (``__all__`` string only) keeps a submodule
alive only if some live consumer imports it through the package.
Test imports are deliberately NOT roots: a module only tests exercise
has no production caller — exactly the state worth surfacing (today:
``optim/compression.py``).  Intentional orphans
carry a module-level ``# repro: noqa[R6]`` and stay visible in the
JSON report.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Set

from repro.analysis.rules import base

_DASH_M = re.compile(r"-m\s+(repro(?:\.\w+)+)")
_MODPATH = re.compile(r"repro(?:\.\w+)+")
_REF_DIRS = ("src", "tests", "benchmarks", "examples")
_ROOT_DIRS = ("benchmarks", "examples")


class DeadCodeRule(base.ProjectRule):
    id = "R6"
    name = "dead-code"

    # -- per-file: unused imports ---------------------------------------
    def check(self, mi: base.ModuleInfo) -> List[base.Finding]:
        fname = os.path.basename(mi.path)
        if fname == "__init__.py":
            return []
        out: List[base.Finding] = []
        bindings: Dict[str, ast.stmt] = {}
        for node in ast.walk(mi.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    bindings[(a.asname or a.name).split(".")[0]] = node
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__":
                    continue
                for a in node.names:
                    if a.name == "*":
                        return []           # can't reason about the file
                    bindings[a.asname or a.name] = node
        if not bindings:
            return out
        used: Set[str] = set()
        for node in ast.walk(mi.tree):
            if isinstance(node, ast.Name) and not isinstance(
                    getattr(node, "_repro_parent", None),
                    (ast.Import, ast.ImportFrom)):
                used.add(node.id)
            elif isinstance(node, ast.Constant) and \
                    isinstance(node.value, str):
                used.add(node.value)        # __all__ / getattr strings
            elif isinstance(node, ast.Attribute):
                used.add(node.attr)
        for name, node in sorted(bindings.items(),
                                 key=lambda kv: kv[1].lineno):
            if name not in used:
                out.append(self.finding(
                    mi, node, f"imported name {name!r} is never used"))
        return out

    # -- project: orphan modules ----------------------------------------
    def check_project(self, modules: List[base.ModuleInfo],
                      repo_root: Optional[str]) -> List[base.Finding]:
        if repo_root is None:
            return []
        src_root = os.path.join(repo_root, "src")
        infos = self._parse_tree(repo_root)
        mod_of_path = {p: self._module_name(p, src_root)
                       for p in infos if p.startswith(src_root)}
        all_mods = {m for m in mod_of_path.values() if m}
        exports = self._export_tables(infos, mod_of_path)
        edges = {m: set() for m in all_mods}
        for path, info in infos.items():
            src_mod = mod_of_path.get(path)
            for target in self._imported_modules(info, all_mods, exports):
                if src_mod:                 # src -> src dependency edge
                    edges[src_mod].add(target)
        alive: Set[str] = set()
        queue: List[str] = []
        for path, info in infos.items():
            rel = os.path.relpath(path, repo_root)
            if rel.split(os.sep)[0] in _ROOT_DIRS:
                queue.extend(self._imported_modules(info, all_mods, exports))
            for m in self._entry_refs(info, mod_of_path.get(path)):
                if m in all_mods:
                    queue.append(m)
        queue.extend(self._workflow_refs(repo_root, all_mods))
        while queue:
            m = queue.pop()
            if m in alive:
                continue
            alive.add(m)
            queue.extend(edges.get(m, ()))
            # a live module keeps its package __init__s live
            parts = m.split(".")
            for i in range(1, len(parts)):
                queue.append(".".join(parts[:i]))
        out: List[base.Finding] = []
        linted = {m.path for m in modules}
        for path, mod in sorted(mod_of_path.items()):
            if not mod or mod in alive:
                continue
            if os.path.basename(path) in ("__init__.py", "__main__.py") or \
                    mod.startswith("repro.analysis"):
                continue
            if path not in linted:
                continue                    # only report on linted files
            out.append(base.Finding(
                self.id, path, 1, 0,
                f"module {mod} is an orphan: no production caller "
                "(examples/benchmarks/-m entry points) reaches it",
            ))
        return out

    # -- helpers ---------------------------------------------------------
    def _parse_tree(self, repo_root: str) -> Dict[str, base.ModuleInfo]:
        infos: Dict[str, base.ModuleInfo] = {}
        for d in _REF_DIRS:
            top = os.path.join(repo_root, d)
            for dirpath, dirnames, filenames in os.walk(top):
                dirnames[:] = [x for x in dirnames
                               if x not in ("__pycache__", ".git")]
                for f in filenames:
                    if not f.endswith(".py"):
                        continue
                    path = os.path.join(dirpath, f)
                    try:
                        with open(path, encoding="utf-8") as fh:
                            src = fh.read()
                        infos[path] = base.ModuleInfo(
                            path, src, ast.parse(src))
                    except (OSError, SyntaxError):
                        continue
        return infos

    def _module_name(self, path: str, src_root: str) -> Optional[str]:
        rel = os.path.relpath(path, src_root)
        if rel.startswith(".."):
            return None
        parts = rel[:-3].split(os.sep)      # strip .py
        if parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    def _export_tables(self, infos, mod_of_path) -> Dict[str, Dict[str, str]]:
        """package -> {exported name: defining submodule} from each
        ``__init__.py``'s import statements."""
        tables: Dict[str, Dict[str, str]] = {}
        for path, info in infos.items():
            if os.path.basename(path) != "__init__.py":
                continue
            pkg = mod_of_path.get(path)
            if not pkg:
                continue
            table: Dict[str, str] = {}
            for node in ast.walk(info.tree):
                if isinstance(node, ast.ImportFrom) and node.module:
                    mod = node.module if node.level == 0 else \
                        pkg + "." + node.module
                    for a in node.names:
                        if a.name != "*":
                            table[a.asname or a.name] = mod
                elif isinstance(node, ast.Import):
                    for a in node.names:
                        table[(a.asname or a.name).split(".")[0]] = a.name
            tables[pkg] = table
        return tables

    def _imported_modules(self, info: base.ModuleInfo, all_mods: Set[str],
                          exports) -> List[str]:
        """src modules this file depends on, with from-package imports
        resolved through __init__ export tables.  In an ``__init__.py``,
        a binding only creates an edge when the init body uses the name
        itself — pure re-exports (``__all__`` strings) don't pin their
        submodule; consumers importing through the package do."""
        is_init = os.path.basename(info.path) == "__init__.py"
        used: Set[str] = set()
        if is_init:
            used = {n.id for n in ast.walk(info.tree)
                    if isinstance(n, ast.Name)}
        deps: List[str] = []
        for node in ast.walk(info.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    bound = (a.asname or a.name).split(".")[0]
                    if a.name in all_mods and \
                            (not is_init or bound in used):
                        deps.append(a.name)
            elif isinstance(node, ast.ImportFrom):
                if node.level or not node.module or \
                        not node.module.startswith("repro"):
                    continue
                for a in node.names:
                    if is_init and (a.asname or a.name) not in used:
                        continue
                    full = f"{node.module}.{a.name}"
                    if full in all_mods:    # from pkg import submodule
                        deps.append(full)
                    elif node.module in all_mods:
                        # from pkg import name: resolve through the
                        # package __init__'s re-export table
                        target = exports.get(node.module, {}).get(a.name)
                        deps.append(target if target in all_mods
                                    else node.module)
        return deps

    def _entry_refs(self, info: base.ModuleInfo,
                    own_mod: Optional[str]) -> List[str]:
        """Execution-surface references: ``-m repro.x.y`` in source text
        plus bare ``"repro.x.y"`` string literals outside docstrings
        (subprocess argv style, ``["-m", "repro.launch.dryrun"]``)."""
        refs = [m for m in _DASH_M.findall(info.source) if m != own_mod]
        doc_positions = set()
        for node in ast.walk(info.tree):
            if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                                 ast.AsyncFunctionDef)):
                body = node.body
                if body and isinstance(body[0], ast.Expr) and \
                        isinstance(body[0].value, ast.Constant) and \
                        isinstance(body[0].value.value, str):
                    doc_positions.add(id(body[0].value))
        for node in ast.walk(info.tree):
            if isinstance(node, ast.Constant) and \
                    isinstance(node.value, str) and \
                    id(node) not in doc_positions and \
                    _MODPATH.fullmatch(node.value) and \
                    node.value != own_mod:
                refs.append(node.value)
        return refs

    def _workflow_refs(self, repo_root: str, all_mods: Set[str]) -> List[str]:
        refs: List[str] = []
        wf = os.path.join(repo_root, ".github", "workflows")
        if not os.path.isdir(wf):
            return refs
        for f in os.listdir(wf):
            if f.endswith((".yml", ".yaml")):
                try:
                    with open(os.path.join(wf, f), encoding="utf-8") as fh:
                        refs.extend(m for m in _DASH_M.findall(fh.read())
                                    if m in all_mods)
                except OSError:
                    continue
        return refs
