"""Rule registry for the repro.analysis linter (R1-R6)."""
from repro.analysis.rules.base import Finding, ModuleInfo, ProjectRule, Rule
from repro.analysis.rules.deadcode import DeadCodeRule
from repro.analysis.rules.donation import DonationRule
from repro.analysis.rules.host_sync import HostSyncRule
from repro.analysis.rules.randomness import KeyReuseRule
from repro.analysis.rules.retrace import RetraceRule
from repro.analysis.rules.traced import TracedBranchRule

#: instantiation order == report order
ALL_RULES = (TracedBranchRule(), KeyReuseRule(), HostSyncRule(),
             RetraceRule(), DonationRule(), DeadCodeRule())

RULE_DOCS = {r.id: r.name for r in ALL_RULES}

__all__ = ["ALL_RULES", "RULE_DOCS", "Finding", "ModuleInfo", "Rule",
           "ProjectRule", "DeadCodeRule", "DonationRule", "HostSyncRule",
           "KeyReuseRule", "RetraceRule", "TracedBranchRule"]
