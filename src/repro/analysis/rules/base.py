"""Shared AST machinery for the repro.analysis lint rules.

Every rule works on a :class:`ModuleInfo`: a parsed module with parent
links, an import-alias table (so ``jnp.where`` resolves to
``jax.numpy.where`` whatever the file imported it as), and helpers for the
two questions most rules ask — "is this function traced by jax?" and
"does this expression produce / derive from a device array?".

The analysis is deliberately file-local and name-based (no type
inference): rules are tuned so the repo's own ``src/`` is clean, false
positives are silenced with ``# repro: noqa[Rn]`` at the finding line,
and anything requiring whole-program reasoning lives in the one project
rule (R6, rules.deadcode).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Set

#: transforms whose function argument is traced (its body must not branch
#: on traced values in Python)
TRACE_WRAPPERS = {
    "jax.jit", "jax.pmap", "jax.vmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.custom_vjp", "jax.custom_jvp",
    "jax.lax.scan", "jax.lax.map", "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.cond", "jax.lax.switch", "jax.lax.associative_scan",
    "jax.experimental.shard_map.shard_map",
    "jax.experimental.checkify.checkify",
    "jax.experimental.pallas.pallas_call",
}

#: call prefixes that produce device arrays
DEVICE_PREFIXES = ("jax.numpy.", "jax.lax.", "jax.random.", "jax.nn.",
                   "jax.scipy.", "jax.tree.", "jax.tree_util.")

#: attribute reads that are static metadata, not traced values
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "aval"}


@dataclasses.dataclass
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        sup = "  (noqa)" if self.suppressed else ""
        return f"{self.path}:{self.line}:{self.col}: " \
               f"{self.rule} {self.message}{sup}"


class Rule:
    """Per-file rule: subclasses set ``id``/``name`` and implement
    :meth:`check`."""

    id = "R0"
    name = "base"

    def check(self, mi: "ModuleInfo") -> List[Finding]:
        raise NotImplementedError

    def finding(self, mi: "ModuleInfo", node: ast.AST,
                message: str) -> Finding:
        return Finding(self.id, mi.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)


class ProjectRule(Rule):
    """Whole-file-set rule (R6): sees every linted module at once plus the
    reference modules around the source tree."""

    def check_project(self, modules: List["ModuleInfo"],
                      repo_root: Optional[str]) -> List[Finding]:
        raise NotImplementedError

    def check(self, mi: "ModuleInfo") -> List[Finding]:
        return []


def _collect_aliases(tree: ast.AST) -> Dict[str, str]:
    """Name -> dotted module/attribute path, from every import statement."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    root = a.name.split(".")[0]
                    aliases.setdefault(root, root)
        elif isinstance(node, ast.ImportFrom):
            if node.level or node.module is None:
                continue                      # relative imports stay local
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def annotate_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._repro_parent = node            # type: ignore[attr-defined]


def parents(node: ast.AST) -> Iterable[ast.AST]:
    cur = getattr(node, "_repro_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_repro_parent", None)


class ModuleInfo:
    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path
        self.source = source
        self.tree = tree
        self.aliases = _collect_aliases(tree)
        annotate_parents(tree)
        self._traced: Optional[Set[ast.AST]] = None

    # -- name resolution -------------------------------------------------
    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted path of a Name/Attribute chain with import aliases
        resolved: ``jnp.sum`` -> ``jax.numpy.sum``.  None for anything
        that is not a plain dotted chain."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.aliases.get(node.id, node.id)
        return ".".join([root] + list(reversed(parts)))

    def is_device_call(self, node: ast.AST) -> bool:
        """Does this Call produce a device array (by name)?"""
        if not isinstance(node, ast.Call):
            return False
        path = self.resolve(node.func)
        if path is None:
            return False
        return path.startswith(DEVICE_PREFIXES) or path in (
            "jax.device_put", "jax.block_until_ready", "jax.eval_shape")

    # -- traced-function detection ---------------------------------------
    def traced_functions(self) -> Set[ast.AST]:
        """FunctionDef/Lambda nodes whose bodies run under a jax trace:
        decorated with / passed to a TRACE_WRAPPER (or ``*.defvjp``), plus
        everything nested inside one."""
        if self._traced is not None:
            return self._traced
        defs: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)
        traced: Set[ast.AST] = set()

        def mark_arg(arg: ast.AST) -> None:
            if isinstance(arg, ast.Lambda):
                traced.add(arg)
            elif isinstance(arg, ast.Name):
                for d in defs.get(arg.id, []):
                    traced.add(d)

        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    path = self.resolve(target)
                    if path in TRACE_WRAPPERS:
                        traced.add(node)
                    elif path in ("functools.partial", "partial") and \
                            isinstance(dec, ast.Call) and dec.args and \
                            self.resolve(dec.args[0]) in TRACE_WRAPPERS:
                        traced.add(node)
            if not isinstance(node, ast.Call):
                continue
            path = self.resolve(node.func)
            if path in TRACE_WRAPPERS:
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    mark_arg(arg)
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "defvjp":
                for arg in node.args:
                    mark_arg(arg)
        # closure: defs nested inside a traced def run during its trace
        changed = True
        while changed:
            changed = False
            for node in ast.walk(self.tree):
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef, ast.Lambda)):
                    continue
                if node in traced:
                    continue
                if any(p in traced for p in parents(node)):
                    traced.add(node)
                    changed = True
        self._traced = traced
        return traced


def device_tainted_names(mi: ModuleInfo, fn: ast.AST,
                         extra_sources=()) -> Set[str]:
    """Names in ``fn`` assigned (directly or transitively) from device-
    array-producing calls: ``jax.*`` calls, calls to private ``self._*``
    methods (engine jit seams by convention), calls to names bound from
    ``jax.jit(...)``, and ``extra_sources``."""
    jitted: Set[str] = set(extra_sources)
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            path = mi.resolve(node.value.func)
            if path in ("jax.jit", "jax.pmap"):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        jitted.add(t.id)

    def value_tainted(node: ast.AST, taint: Set[str]) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                if mi.is_device_call(sub):
                    return True
                path = mi.resolve(sub.func)
                if path is not None and path.split(".")[0] in jitted:
                    return True
                if isinstance(sub.func, ast.Attribute) and \
                        isinstance(sub.func.value, ast.Name) and \
                        sub.func.value.id == "self" and \
                        sub.func.attr.startswith("_"):
                    return True
            elif isinstance(sub, ast.Name) and sub.id in taint:
                if not _is_static_access(sub):
                    return True
        return False

    taint: Set[str] = set()
    for _ in range(2):                      # two passes ~= fixpoint here
        for node in ast.walk(fn):
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AugAssign):
                targets, value = [node.target], node.value
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                targets, value = [node.target], node.iter
            else:
                continue
            if not value_tainted(value, taint):
                continue
            for t in targets:
                taint.update(_target_names(t))
    return taint


def _target_names(t: ast.AST) -> List[str]:
    """Names actually bound by an assignment target — the base of a
    subscript/attribute store, not its index expression (``out[path] = m``
    taints ``out``, never ``path``)."""
    if isinstance(t, ast.Name):
        return [t.id]
    if isinstance(t, (ast.Tuple, ast.List)):
        return [n for e in t.elts for n in _target_names(e)]
    if isinstance(t, ast.Starred):
        return _target_names(t.value)
    if isinstance(t, (ast.Subscript, ast.Attribute)):
        return _target_names(t.value)
    return []


def _is_static_access(name_node: ast.Name) -> bool:
    """True when the name is only read through static metadata
    (``x.shape`` / ``len(x)`` / ``isinstance(x, ...)``)."""
    parent = getattr(name_node, "_repro_parent", None)
    if isinstance(parent, ast.Attribute) and parent.attr in STATIC_ATTRS:
        return True
    if isinstance(parent, ast.Call) and isinstance(parent.func, ast.Name) \
            and parent.func.id in ("len", "isinstance", "type", "hasattr",
                                   "getattr"):
        return True
    return False


def expr_uses_device_value(mi: ModuleInfo, node: ast.AST,
                           taint: Set[str]) -> bool:
    """Does evaluating ``node`` touch a (likely) device value — a tainted
    name or a device-producing call — through anything other than static
    metadata access?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and mi.is_device_call(sub):
            return True
        if isinstance(sub, ast.Name) and sub.id in taint \
                and not _is_static_access(sub):
            return True
    return False
