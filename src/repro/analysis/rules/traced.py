"""R1 — Python-level branching on traced values inside jitted functions.

``if`` / ``while`` / conditional expressions whose test involves a traced
value (a parameter of the traced function, or anything derived from a
``jax.*`` call) force a concretization error at best and a silent
trace-time specialization at worst.  Inside a traced function, control
flow on array values belongs in ``jnp.where`` / ``lax.cond`` /
``lax.while_loop``.

Static-metadata tests (``x.shape``, ``x.ndim``, ``len(x)``,
``isinstance``) are fine and excluded; branching on closure config
(Python bools/ints captured from outside) is fine too — only parameters
of the traced function and locally derived device values count.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.rules import base


class TracedBranchRule(base.Rule):
    id = "R1"
    name = "traced-branch"

    def check(self, mi: base.ModuleInfo) -> List[base.Finding]:
        out: List[base.Finding] = []
        traced = mi.traced_functions()
        for fn in traced:
            if isinstance(fn, ast.Lambda):
                continue                 # lambdas cannot contain if/while
            taint: Set[str] = {a.arg for a in fn.args.args
                               + fn.args.posonlyargs + fn.args.kwonlyargs}
            # params with a default are the closure-capture idiom
            # (``def body(c, x, kind=kind)``): jax transforms pass traced
            # operands positionally, so default-valued params are static
            pos = fn.args.posonlyargs + fn.args.args
            if fn.args.defaults:
                taint -= {a.arg for a in pos[-len(fn.args.defaults):]}
            taint -= {a.arg for a, d in zip(fn.args.kwonlyargs,
                                            fn.args.kw_defaults)
                      if d is not None}
            taint |= base.device_tainted_names(mi, fn, extra_sources=())
            for node in ast.walk(fn):
                # nested defs are traced too but get their own visit
                if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                    owner = next(
                        (p for p in base.parents(node)
                         if isinstance(p, (ast.FunctionDef,
                                           ast.AsyncFunctionDef))), None)
                    if owner is not fn:
                        continue
                    if base.expr_uses_device_value(mi, node.test, taint):
                        kind = {"If": "if", "While": "while",
                                "IfExp": "conditional expression"}[
                                    type(node).__name__]
                        out.append(self.finding(
                            mi, node,
                            f"Python {kind} on a traced value inside "
                            f"jitted function {getattr(fn, 'name', '?')!r}"
                            " — use jnp.where / lax.cond instead"))
        return out
