"""Child process for the 16-host-device sharded equivalence tests.

Run by tests/test_sharded_engine.py in a SUBPROCESS (own XLA_FLAGS, like
tests/test_dryrun_small.py) so the forced host-device count never disturbs
the parent's single-device jax.  Runs all three engines — sequential,
batched, and client-sharded — on the same fixed-seed setting and prints one
JSON line per scheme with the pairwise max param diffs.

  REPRO_HOST_DEVICES=16 python tests/sharded_equiv_child.py --family cnn
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_HOST_DEVICES", "16"))

import argparse
import json

import jax
import numpy as np

from repro.configs import ARCHS, CNNS, HeliosConfig, reduced
from repro.data.federated import partition_by_topic, partition_noniid
from repro.data.synthetic import class_gaussian_images, markov_topic_tokens
from repro.federated import (BatchedFLRun, FLRun, ShardedFLRun, make_fleet,
                             setup_clients)


def _max_param_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                   - np.asarray(y, np.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _setting(family: str):
    if family == "cnn":
        cfg = reduced(CNNS["lenet"])
        imgs, labels = class_gaussian_images(
            1200, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0)
        ti, tl = class_gaussian_images(
            256, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=9)
        parts = partition_noniid(labels, 4, shards_per_client=4)
        return (cfg, {"images": imgs, "labels": labels},
                {"images": ti, "labels": tl}, parts)
    cfg = reduced(ARCHS["deepseek-7b"])                  # small dense LM
    tokens, topics = markov_topic_tokens(240, 32, 64, n_topics=8, seed=0)
    test_tokens, _ = markov_topic_tokens(64, 32, 64, n_topics=8, seed=9)
    parts = partition_by_topic(topics, 4, topics_per_client=2)
    return cfg, {"tokens": tokens}, {"tokens": test_tokens}, parts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", choices=["cnn", "lm"], default="cnn")
    ap.add_argument("--schemes", default="helios,syn,st_only")
    ap.add_argument("--rounds", type=int, default=3)
    args = ap.parse_args()

    cfg, train, test, parts = _setting(args.family)
    for scheme in args.schemes.split(","):
        engines = {}
        hists = {}
        for name, cls in (("seq", FLRun), ("bat", BatchedFLRun),
                          ("shd", ShardedFLRun)):
            hcfg = HeliosConfig()
            clients = setup_clients(make_fleet(2, 2), parts, hcfg)
            run = cls(cfg, hcfg, scheme, clients, train, test,
                      local_steps=2, batch_size=4 if args.family == "lm"
                      else 32, lr=0.1, seed=0, eval_batch=64)
            hists[name] = run.run_sync(args.rounds)
            engines[name] = run
        rec = {
            "family": args.family, "scheme": scheme,
            "n_devices": len(jax.devices()),
            "mesh_shards": int(engines["shd"]._mesh.devices.size),
            "diff_seq_bat": _max_param_diff(engines["seq"].global_params,
                                            engines["bat"].global_params),
            "diff_seq_shd": _max_param_diff(engines["seq"].global_params,
                                            engines["shd"].global_params),
            "diff_bat_shd": _max_param_diff(engines["bat"].global_params,
                                            engines["shd"].global_params),
            "ratios_equal": all(
                np.allclose(a["ratios"], b["ratios"], atol=1e-6)
                for a, b in zip(hists["seq"], hists["shd"])),
            "times_equal": all(
                abs(a["time"] - b["time"]) < 1e-9
                for a, b in zip(hists["seq"], hists["shd"])),
        }
        print("EQUIV " + json.dumps(rec), flush=True)


if __name__ == "__main__":
    main()
