"""The three-way equivalence wall: FLRun ↔ BatchedFLRun ↔ ShardedFLRun.

The client-sharded engine must be a pure execution-layout change on top of
the batched engine: for a fixed seed all three engines produce the same
global params (atol 1e-5 over 3 rounds), the same per-round straggler
selected fractions, and the same simulated wall times — for the CNN testbed
AND a dense-LM family.  In-process tests run the sharded engine on this
process's (single-device) mesh; the multi-device path runs in a
16-host-device SUBPROCESS (own XLA_FLAGS, tests/sharded_equiv_child.py)
exactly like tests/test_dryrun_small.py.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.analysis import contracts as CT
from repro.configs import ARCHS, CNNS, HeliosConfig, reduced
from repro.data.federated import partition_by_topic, partition_noniid
from repro.data.synthetic import class_gaussian_images, markov_topic_tokens
from repro.federated import (BatchedFLRun, FLRun, ShardedFLRun, make_fleet,
                             setup_clients)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setting():
    cfg = reduced(CNNS["lenet"])
    imgs, labels = class_gaussian_images(1200, cfg.image_size,
                                         cfg.in_channels, cfg.num_classes,
                                         seed=0)
    ti, tl = class_gaussian_images(256, cfg.image_size, cfg.in_channels,
                                   cfg.num_classes, seed=9)
    parts = partition_noniid(labels, 4, shards_per_client=4)
    return cfg, {"images": imgs, "labels": labels}, \
        {"images": ti, "labels": tl}, parts


@pytest.fixture(scope="module")
def lm_setting():
    cfg = reduced(ARCHS["deepseek-7b"])
    tokens, topics = markov_topic_tokens(240, 32, 64, n_topics=8, seed=0)
    test_tokens, _ = markov_topic_tokens(64, 32, 64, n_topics=8, seed=9)
    parts = partition_by_topic(topics, 4, topics_per_client=2)
    return cfg, {"tokens": tokens}, {"tokens": test_tokens}, parts


def _make(setting, cls, scheme, hcfg=None, batch_size=32, **kw):
    cfg, train, test, parts = setting
    hcfg = hcfg or HeliosConfig()
    clients = setup_clients(make_fleet(2, 2), parts, hcfg)
    return cls(cfg, hcfg, scheme, clients, train, test,
               local_steps=2, batch_size=batch_size, lr=0.1, seed=0,
               eval_batch=64, **kw)


def _max_param_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                   - np.asarray(y, np.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("scheme", ["helios", "syn", "st_only"])
def test_sharded_matches_sequential_cnn(setting, scheme):
    """Fixed seed, 3 rounds: same global params, ratios, volumes, times."""
    seq = _make(setting, FLRun, scheme)
    shd = _make(setting, ShardedFLRun, scheme)
    hs = seq.run_sync(3)
    hh = shd.run_sync(3)
    assert _max_param_diff(seq.global_params, shd.global_params) < 1e-5
    for a, b in zip(hs, hh):
        np.testing.assert_allclose(a["ratios"], b["ratios"], atol=1e-6)
        np.testing.assert_allclose(a["volumes"], b["volumes"], atol=1e-6)
        assert abs(a["time"] - b["time"]) < 1e-9


def test_sharded_matches_batched_lm(lm_setting):
    """The dense-LM family federates identically through the sharded path
    (generic axis-driven masks + scores under shard_map)."""
    bat = _make(lm_setting, BatchedFLRun, "helios", batch_size=4)
    shd = _make(lm_setting, ShardedFLRun, "helios", batch_size=4)
    hb = bat.run_sync(3)
    hh = shd.run_sync(3)
    assert _max_param_diff(bat.global_params, shd.global_params) < 1e-5
    for a, b in zip(hb, hh):
        np.testing.assert_allclose(a["ratios"], b["ratios"], atol=1e-6)
        assert abs(a["ce"] - b["ce"]) < 1e-4


def test_sharded_masked_mean(setting):
    """The psum'd per-coordinate masked mean matches the sequential
    list-of-pytrees reference path."""
    hcfg = HeliosConfig(aggregation="masked_mean")
    seq = _make(setting, FLRun, "helios", hcfg=hcfg)
    shd = _make(setting, ShardedFLRun, "helios", hcfg=hcfg)
    seq.run_sync(2)
    shd.run_sync(2)
    assert _max_param_diff(seq.global_params, shd.global_params) < 1e-5


def test_sharded_shape_stable_no_recompile(setting):
    """Across many sampled cohorts the round program compiles EXACTLY once:
    cohort-shape-stable padding + traced soft/valid flags."""
    shd = _make(setting, ShardedFLRun, "helios", participation=2)
    shd.run_sync(5, eval_every=0)
    assert len({tuple(c) for c in shd.cohort_log}) > 1   # draws did vary
    # one round program total — asserted through the contracts API
    rep = CT.compile_report(shd)
    assert rep.get("round"), rep
    with CT.override(True):
        CT.check_compile_budget(shd)


def test_sharded_population_state_roundtrip(setting):
    """sync_client_states materializes rows; checkpoint-style snapshots see
    advanced cycles and compressed straggler masks."""
    shd = _make(setting, ShardedFLRun, "helios")
    shd.run_sync(2)
    shd.sync_client_states()
    for c in shd.clients:
        if c.is_straggler:
            assert int(c.helios_state["cycle"]) == 2
            fracs = [float(m.mean())
                     for m in c.helios_state["masks"].values()]
            assert min(fracs) < 0.9
        else:
            assert int(c.helios_state["cycle"]) == 0


def _run_child(family, schemes="helios,syn,st_only", rounds=3):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_HOST_DEVICES="16")
    cmd = [sys.executable, os.path.join(REPO, "tests",
                                        "sharded_equiv_child.py"),
           "--family", family, "--schemes", schemes,
           "--rounds", str(rounds)]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=1800)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    recs = [json.loads(line[len("EQUIV "):])
            for line in r.stdout.splitlines() if line.startswith("EQUIV ")]
    assert len(recs) == len(schemes.split(","))
    return recs


@pytest.mark.slow
def test_sharded_equivalence_16dev_cnn():
    """CNN three-way wall on a real 16-host-device mesh (subprocess)."""
    for rec in _run_child("cnn"):
        assert rec["n_devices"] == 16
        assert rec["mesh_shards"] == 4          # capped at the cohort size
        assert rec["diff_seq_bat"] < 1e-5, rec
        assert rec["diff_seq_shd"] < 1e-5, rec
        assert rec["diff_bat_shd"] < 1e-5, rec
        assert rec["ratios_equal"] and rec["times_equal"], rec


@pytest.mark.slow
def test_sharded_equivalence_16dev_lm():
    """Dense-LM three-way wall on a 16-host-device mesh (subprocess)."""
    for rec in _run_child("lm", schemes="helios"):
        assert rec["n_devices"] == 16
        assert rec["diff_seq_shd"] < 1e-5, rec
        assert rec["diff_bat_shd"] < 1e-5, rec
        assert rec["ratios_equal"] and rec["times_equal"], rec
