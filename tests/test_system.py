"""End-to-end behaviour tests for the paper's system: the full Helios loop
on the paper's own testbed (FULL LeNet / synthetic MNIST at calibrated
difficulty, 2 capable + 2 Table-I stragglers) reproduces the qualitative
claims: faster cycles, better accuracy at equal wall-clock."""
import pytest

from repro.configs import CNNS, HeliosConfig
from repro.data.federated import partition_noniid
from repro.data.synthetic import class_gaussian_images
from repro.federated import FLRun, make_fleet, setup_clients


@pytest.fixture(scope="module")
def world():
    cfg = CNNS["lenet"]                      # FULL paper config, 28x28
    imgs, labels = class_gaussian_images(2000, cfg.image_size,
                                         cfg.in_channels, cfg.num_classes,
                                         seed=0, noise=6.0)
    ti, tl = class_gaussian_images(512, cfg.image_size, cfg.in_channels,
                                   cfg.num_classes, seed=77, noise=6.0)
    parts = partition_noniid(labels, 4, shards_per_client=4)
    return cfg, imgs, labels, ti, tl, parts


@pytest.fixture(scope="module")
def histories(world):
    cfg, imgs, labels, ti, tl, parts = world

    def run(scheme, rounds):
        hcfg = HeliosConfig()
        clients = setup_clients(make_fleet(2, 2), parts, hcfg)
        r = FLRun(cfg, hcfg, scheme, clients,
                  {"images": imgs, "labels": labels},
                  {"images": ti, "labels": tl},
                  local_steps=2, lr=0.02)
        if scheme in ("syn", "helios", "st_only", "random"):
            return r.run_sync(rounds)
        return r.run_async(rounds)

    return {"syn": run("syn", 9), "helios": run("helios", 26)}


def _acc_at_time(hist, t):
    best = 0.0
    for h in hist:
        if h["time"] <= t:
            best = max(best, h["acc"])
    return best


def test_helios_beats_syn_at_equal_time(histories):
    """Paper §VII.B: at fixed wall-clock budgets, Helios > Syn FL (the
    straggler gates Syn's cycle)."""
    t_end = histories["syn"][-1]["time"]
    wins = 0
    for frac in (0.4, 0.6, 0.8, 1.0):
        a_h = _acc_at_time(histories["helios"], frac * t_end)
        a_s = _acc_at_time(histories["syn"], frac * t_end)
        wins += a_h >= a_s
    assert wins >= 3, (histories["syn"], histories["helios"])


def test_speedup_factor_in_paper_range(histories):
    """Cycle-time speedup vs Syn FL lands in the paper's reported range
    (up to 2.5x with Table-I stragglers)."""
    h_syn, h_hel = histories["syn"], histories["helios"]
    speedup = (h_syn[-1]["time"] / h_syn[-1]["cycle"]) / \
        (h_hel[-1]["time"] / h_hel[-1]["cycle"])
    assert 1.5 <= speedup <= 4.5, speedup


def test_time_to_accuracy_speedup(histories):
    """Time to reach the mid-training accuracy target: Helios >= 1.5x faster."""
    target = 0.9 * histories["syn"][-1]["acc"]

    def t_to(hist):
        for h in hist:
            if h["acc"] >= target:
                return h["time"]
        return float("inf")

    t_syn, t_hel = t_to(histories["syn"]), t_to(histories["helios"])
    assert t_hel < t_syn, (t_hel, t_syn)
    assert t_syn / t_hel >= 1.5, t_syn / t_hel


def test_helios_learns_to_high_accuracy(histories):
    assert histories["helios"][-1]["acc"] > 0.55
