"""Partial participation: schedule determinism, state persistence, rotation
on rejoin, and async snapshot bookkeeping.

A population's Helios state must be OWNED by the server across rounds: a
client that sits out keeps masks/scores/skip_counts bit-identical, samplers
reproduce the identical participant schedule from a fixed seed on every
engine, and long-skipped units are forcibly rotated back in the next time
their client is drawn.
"""
import jax
import numpy as np
import pytest

from repro.configs import CNNS, HeliosConfig, reduced
from repro.data.federated import partition_iid, partition_iid_lazy
from repro.data.synthetic import class_gaussian_images
from repro.federated import (AsyncFLRun, BatchedFLRun, FLRun, ShardedFLRun,
                             make_fleet, setup_clients)


@pytest.fixture(scope="module")
def setting():
    cfg = reduced(CNNS["lenet"])
    imgs, labels = class_gaussian_images(800, cfg.image_size,
                                         cfg.in_channels, cfg.num_classes,
                                         seed=0)
    ti, tl = class_gaussian_images(128, cfg.image_size, cfg.in_channels,
                                   cfg.num_classes, seed=9)
    parts = partition_iid(len(labels), 6, seed=0)
    return cfg, {"images": imgs, "labels": labels}, \
        {"images": ti, "labels": tl}, parts


def _make(setting, cls, scheme="helios", n=6, **kw):
    cfg, train, test, parts = setting
    hcfg = HeliosConfig()
    clients = setup_clients(make_fleet(n - n // 2, n // 2), parts, hcfg)
    return cls(cfg, hcfg, scheme, clients, train, test,
               local_steps=1, batch_size=8, lr=0.1, seed=0, eval_batch=64,
               **kw)


def _state_leaves(state):
    return [np.asarray(x) for x in jax.tree.leaves(state)]


@pytest.mark.parametrize("sampler", ["uniform", "time_weighted"])
def test_identical_schedules_across_engines(setting, sampler):
    """Fixed seed => the three engines draw the exact same cohorts."""
    runs = [_make(setting, cls, participation=3, sampler=sampler)
            for cls in (FLRun, BatchedFLRun, ShardedFLRun)]
    for r in runs:
        r.run_sync(4, eval_every=0)
    assert runs[0].cohort_log == runs[1].cohort_log == runs[2].cohort_log
    assert len(runs[0].cohort_log) == 4
    assert all(len(c) == 3 for c in runs[0].cohort_log)
    # and the sampled-population trajectories stay equivalent
    a = runs[0].global_params
    for other in runs[1:]:
        diff = max(float(np.max(np.abs(np.asarray(x, np.float32)
                                       - np.asarray(y, np.float32))))
                   for x, y in zip(jax.tree.leaves(a),
                                   jax.tree.leaves(other.global_params)))
        assert diff < 1e-5


@pytest.mark.parametrize("scheme", ["scaffold", "fluid", "delayed"])
def test_new_scheme_schedules_identical_across_engines(setting, scheme):
    """The baseline schemes keep the schedule determinism guarantee on
    ALL FOUR engines: time_weighted weights come from the scheme's ONE
    effective_volume hook, so full-volume baselines (scaffold/delayed)
    and soft-training ones (fluid) each draw the exact same cohorts —
    and the sampled trajectories stay one trajectory."""
    runs = [_make(setting, cls, scheme=scheme, participation=3,
                  sampler="time_weighted")
            for cls in (FLRun, AsyncFLRun, BatchedFLRun, ShardedFLRun)]
    for r in runs:
        r.run_sync(4, eval_every=0)
    for other in runs[1:]:
        assert other.cohort_log == runs[0].cohort_log, type(other).__name__
    assert len(runs[0].cohort_log) == 4
    a = runs[0].global_params
    for other in runs[1:]:
        diff = max(float(np.max(np.abs(np.asarray(x, np.float32)
                                       - np.asarray(y, np.float32))))
                   for x, y in zip(jax.tree.leaves(a),
                                   jax.tree.leaves(other.global_params)))
        assert diff < 1e-5, type(other).__name__


def test_skipped_client_state_bit_identical(setting):
    """A client that sits out R rounds keeps its whole Helios state
    bit-for-bit — in both the batched (per-dict) and the sharded
    (population-row) engines."""
    for cls in (BatchedFLRun, ShardedFLRun):
        run = _make(setting, cls, participation=2)
        if cls is ShardedFLRun:
            snap = [_state_leaves(run.client_state(i)) for i in range(6)]
        else:
            snap = [_state_leaves(c.helios_state) for c in run.clients]
        for _ in range(3):
            run.run_sync(1, eval_every=0)
            sampled = set(run.cohort_log[-1])
            for i in range(6):
                cur = _state_leaves(run.client_state(i)
                                    if cls is ShardedFLRun
                                    else run.clients[i].helios_state)
                if i not in sampled:
                    for a, b in zip(snap[i], cur):
                        np.testing.assert_array_equal(a, b)
                snap[i] = cur


def test_capable_rows_never_advance(setting):
    """Capable clients flow through the sharded unified program with the
    soft flag off: their population rows stay at cycle 0 with intact rng."""
    run = _make(setting, ShardedFLRun, participation=4)
    init = {i: _state_leaves(run.client_state(i)) for i in range(6)
            if not run.clients[i].is_straggler}
    run.run_sync(3, eval_every=0)
    for i, leaves in init.items():
        for a, b in zip(leaves, _state_leaves(run.client_state(i))):
            np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("cls", [FLRun, ShardedFLRun])
def test_forced_rotation_fires_on_rejoin(setting, cls):
    """Units whose skip count crossed the rotation threshold while the
    client sat out are forced back into training the round it rejoins."""
    run = _make(setting, cls, participation=2)
    sidx = next(i for i, c in enumerate(run.clients) if c.is_straggler)
    # push ONE unit per row far over any threshold (1 + 1/P); forced sets
    # smaller than the round(P*n) budget must preempt the draw outright
    if cls is ShardedFLRun:
        for v in run._pop_state["skip_counts"].values():
            v[sidx, :, 0] = 1000                  # host rows mutate in place
    else:
        st = run.clients[sidx].helios_state
        st["skip_counts"] = {k: v.at[:, 0].set(1000)
                             for k, v in st["skip_counts"].items()}
    for _ in range(12):
        run.run_sync(1, eval_every=0)
        if sidx in run.cohort_log[-1]:
            break
    else:
        pytest.fail("straggler never sampled in 12 rounds")
    state = run.client_state(sidx) if cls is ShardedFLRun \
        else run.clients[sidx].helios_state
    for k, m in state["masks"].items():
        np.testing.assert_array_equal(np.asarray(m)[:, 0],
                                      np.ones_like(np.asarray(m)[:, 0]))
        # ...and the counters reset, so rotation regulation re-arms
        assert int(np.max(np.asarray(state["skip_counts"][k])[:, 0])) == 0


def test_lazy_parts_population(setting):
    """A population set up from the lazy partition trains identically to
    the eager one (index-for-index equal draws)."""
    cfg, train, test, _ = setting
    n = 8
    hcfg = HeliosConfig()
    n_items = len(train["labels"])
    out = {}
    for name, parts in (("eager", partition_iid(n_items, n, seed=1)),
                        ("lazy", partition_iid_lazy(n_items, n, seed=1))):
        clients = setup_clients(make_fleet(n - n // 2, n // 2),
                                parts, hcfg)
        run = ShardedFLRun(cfg, hcfg, "helios", clients, train, test,
                           local_steps=1, batch_size=8, lr=0.1, seed=0,
                           participation=3)
        run.run_sync(2, eval_every=0)
        out[name] = run.global_params
    for a, b in zip(jax.tree.leaves(out["eager"]),
                    jax.tree.leaves(out["lazy"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_snapshot_dict_bounded(setting):
    """Straggler-heavy async run: the snapshot dict stays within
    snapshot_cap + len(clients), and no live anchor is ever evicted."""
    run = _make(setting, FLRun, scheme="afo")
    run.run_async(24, snapshot_cap=2, eval_every=0)
    assert run.snapshot_peak <= 2 + len(run.clients)
    assert run.snapshot_anchor_misses == 0
