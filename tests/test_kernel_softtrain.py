"""Kernel-backed soft-training: the kernel↔reference equivalence wall.

Three layers of pinning (interpret mode on CPU — bit-compatible semantics,
native compile on TPU):

  (a) op level — masked_dense / masked_contract / flash_attention forward
      AND backward match the plain-jnp reference at atol 1e-5, with
      EXACTLY-ZERO gradients for masked-out columns (Helios frozen-neuron
      semantics), on ragged shapes the kernels must pad internally;
  (b) engine level — the FL engines produce the same trajectory with
      ``kernels="pallas"`` as with ``kernels="reference"`` (and the batched
      /sharded/async engines replay the sequential one under both), on the
      CNN testbed and a dense-LM family;
  (c) property level — hypothesis invariants for ``block_align_mask`` (the
      seam that makes Eq. 2 selection structurally skippable): idempotent,
      mask-superset, block-constant output.
"""
import os

# the multi-device CI job forces a host device count before jax initializes
if os.environ.get("REPRO_HOST_DEVICES") and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_HOST_DEVICES"])

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

ATOL = 1e-5


def _maxdiff(a, b):
    return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                   - np.asarray(y, np.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# (a) op-level fwd + bwd equivalence
# ---------------------------------------------------------------------------


def _mask(key, n, frac, block=None):
    m = (jax.random.uniform(key, (n,)) < frac).astype(jnp.float32)
    m = m.at[0].set(1.0)                       # never fully dead
    if block:
        m = ops.block_align_mask(m, block)
    return m


@pytest.mark.parametrize("m,k,n,bn", [
    (32, 48, 96, 32),            # aligned
    (5, 37, 84, 32),             # every axis ragged vs the blocks
    (16, 64, 64, 128),           # block larger than the whole axis
])
@pytest.mark.parametrize("frac", [0.25, 0.6, 1.0])
def test_masked_dense_fwd_bwd(m, k, n, bn, frac):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    um = _mask(jax.random.fold_in(key, 2), n, frac, block=bn)

    got = ops.masked_dense(x, w, um, impl="pallas", block_n=bn)
    want = ops.masked_dense(x, w, um, impl="reference")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)

    def loss(impl):
        return lambda x, w: jnp.sum(
            ops.masked_dense(x, w, um, impl=impl, block_n=bn) ** 2)

    gp = jax.grad(loss("pallas"), argnums=(0, 1))(x, w)
    gr = jax.grad(loss("reference"), argnums=(0, 1))(x, w)
    # blockwise accumulation reorders the float sums: rtol absorbs the
    # magnitude the squared loss puts on the cotangents
    np.testing.assert_allclose(np.asarray(gp[0]), np.asarray(gr[0]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gp[1]), np.asarray(gr[1]),
                               rtol=1e-4, atol=1e-4)
    # frozen-neuron semantics: masked columns get EXACT zero dw
    dead = np.asarray(um) == 0
    assert float(np.max(np.abs(np.asarray(gp[1])[:, dead]), initial=0.0)) == 0.0
    assert float(np.max(np.abs(np.asarray(gr[1])[:, dead]), initial=0.0)) == 0.0


def test_masked_dense_nonaligned_mask_stays_exact():
    """A mask that is NOT block-constant (live block containing dead units)
    must still match W·mask semantics exactly — the kernel output is
    re-multiplied by the unit mask."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (8, 16))
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 64))
    um = _mask(jax.random.fold_in(key, 2), 64, 0.5, block=None)  # unit-level
    got = ops.masked_dense(x, w, um, impl="pallas", block_n=32)
    want = x @ (w * um[None, :])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)
    dead = np.asarray(um) == 0
    dw = jax.grad(lambda w: ops.masked_dense(x, w, um, impl="pallas",
                                             block_n=32).sum())(w)
    assert float(np.max(np.abs(np.asarray(dw)[:, dead]), initial=0.0)) == 0.0


@pytest.mark.parametrize("m,n,k2,bn", [(32, 96, 24, 32), (7, 84, 11, 32)])
@pytest.mark.parametrize("frac", [0.3, 1.0])
def test_masked_contract_fwd_bwd(m, n, k2, bn, frac):
    key = jax.random.PRNGKey(1)
    um = _mask(jax.random.fold_in(key, 2), n, frac, block=bn)
    # h comes through a masked layer, so its dead columns are zero
    h = jax.random.normal(key, (m, n)) * um[None, :]
    w = jax.random.normal(jax.random.fold_in(key, 1), (n, k2))

    got = ops.masked_contract(h, w, um, impl="pallas", block_n=bn)
    want = ops.masked_contract(h, w, um, impl="reference")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)

    def loss(impl):
        return lambda h, w: jnp.sum(
            ops.masked_contract(h, w, um, impl=impl, block_n=bn) ** 2)

    gp = jax.grad(loss("pallas"), argnums=(0, 1))(h, w)
    gr = jax.grad(loss("reference"), argnums=(0, 1))(h, w)
    dead = np.asarray(um) == 0
    # dw dead ROWS exactly zero (the frozen units' weights never move)
    assert float(np.max(np.abs(np.asarray(gp[1])[dead]), initial=0.0)) == 0.0
    np.testing.assert_allclose(np.asarray(gp[1]), np.asarray(gr[1]),
                               rtol=1e-4, atol=1e-4)
    # dh: the pallas path zeroes dead columns (they are dead downstream);
    # live columns must agree with the reference
    np.testing.assert_allclose(np.asarray(gp[0])[:, ~dead],
                               np.asarray(gr[0])[:, ~dead],
                               rtol=1e-4, atol=1e-4)
    assert float(np.max(np.abs(np.asarray(gp[0])[:, dead]), initial=0.0)) == 0.0


@pytest.mark.parametrize("s", [48, 128, 200])      # ragged vs block 128
def test_flash_attention_fwd_bwd(s):
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (2, 3, s, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 3, s, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 3, s, 16))
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)

    def loss(fn):
        return lambda q, k, v: jnp.sum(fn(q, k, v) ** 2)

    gp = jax.grad(loss(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True)), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss(lambda q, k, v: ref.flash_attention_ref(
        q, k, v, causal=True)), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_ops_masked_matmul_ragged_no_crash():
    """Regression: N % block_n != 0 used to crash in unit_mask.reshape —
    the wrapper now pads (zero columns become dead, skipped blocks)."""
    x = jnp.ones((4, 8))
    w = jnp.ones((8, 84))
    um = jnp.ones((84,)).at[40:].set(0.0)
    y = ops.masked_matmul(x, w, um, block_n=32)
    assert y.shape == (4, 84)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x @ (w * um[None, :])), atol=ATOL)


def test_block_granular_selection_keeps_volume():
    """select_masks(block=...) must produce block-constant masks whose
    selected fraction tracks P (NOT the rounded-up degenerate full model a
    unit-scattered selection would align to)."""
    from repro.core import selection as S

    key = jax.random.PRNGKey(0)
    scores = {"mlp": jax.random.uniform(key, (2, 512))}
    forced = {"mlp": jnp.zeros((2, 512), bool)}
    for p in (0.25, 0.5, 0.75):
        masks = S.select_masks(scores, forced, jnp.asarray(p), 0.1,
                               jax.random.fold_in(key, 1), block=128)
        m = np.asarray(masks["mlp"])
        frac = m.mean()
        assert abs(frac - p) <= 0.01, (p, frac)   # nb=4: P lands on 1/4 grid
        blocks = m.reshape(2, 4, 128)
        assert np.all(blocks.max(-1) == blocks.min(-1))  # block-constant


# ---------------------------------------------------------------------------
# (b) engine-level: pallas vs reference trajectories, seq ↔ batched ↔ others
# ---------------------------------------------------------------------------


def _cnn_setting():
    from repro.configs import CNNS, reduced
    from repro.data.federated import partition_iid
    from repro.data.synthetic import class_gaussian_images

    cfg = reduced(CNNS["lenet"])
    imgs, labels = class_gaussian_images(
        256, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0,
        noise=4.0)
    ti, tl = class_gaussian_images(
        64, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=9,
        noise=4.0)
    parts = partition_iid(len(labels), 4, seed=0)
    return cfg, {"images": imgs, "labels": labels}, \
        {"images": ti, "labels": tl}, parts


def _lm_setting():
    from repro.configs import ARCHS, reduced
    from repro.data.federated import partition_by_topic
    from repro.data.synthetic import markov_topic_tokens

    cfg = reduced(ARCHS["deepseek-7b"])            # small dense transformer
    tokens, topics = markov_topic_tokens(240, 32, 64, n_topics=8, seed=0)
    test_tokens, _ = markov_topic_tokens(64, 32, 64, n_topics=8, seed=9)
    parts = partition_by_topic(topics, 4, topics_per_client=2)
    return cfg, {"tokens": tokens}, {"tokens": test_tokens}, parts


def _run(setting, cls, kernels, scheme="helios", rounds=2, **kw):
    from repro.configs import HeliosConfig
    from repro.federated import make_fleet, setup_clients

    cfg, train, test, parts = setting
    hcfg = HeliosConfig(mask_block=16)       # block-granular selection (pools
    # fc0/fc1/mlp at toy widths: the 4-block pooling guard needs n >= 64)
    clients = setup_clients(make_fleet(2, 2), parts, hcfg)
    # ONE knob: the engines derive the kernel skip granularity from
    # HeliosConfig.mask_block (runtime.FLRun.__post_init__)
    run = cls(cfg, hcfg, scheme, clients, train, test, local_steps=2,
              batch_size=4, lr=0.05, seed=0, eval_batch=48,
              kernels=kernels, **kw)
    run.run_sync(rounds, eval_every=rounds)
    return run


@pytest.fixture(scope="module")
def cnn_runs():
    from repro.federated import BatchedFLRun, FLRun
    setting = _cnn_setting()
    return {("seq", k): _run(setting, FLRun, k)
            for k in ("reference", "pallas")} | \
        {("bat", "pallas"): _run(setting, BatchedFLRun, "pallas")}


@pytest.fixture(scope="module")
def lm_runs():
    from repro.federated import BatchedFLRun, FLRun
    setting = _lm_setting()
    return {("seq", k): _run(setting, FLRun, k)
            for k in ("reference", "pallas")} | \
        {("bat", "pallas"): _run(setting, BatchedFLRun, "pallas")}


def test_cnn_pallas_matches_reference(cnn_runs):
    """Same seed, 2 rounds of helios soft-training: the kernel substrate
    reproduces the reference trajectory (params atol 1e-5)."""
    d = _maxdiff(cnn_runs[("seq", "reference")].global_params,
                 cnn_runs[("seq", "pallas")].global_params)
    assert d < ATOL, d


def test_cnn_batched_pallas_matches_sequential(cnn_runs):
    d = _maxdiff(cnn_runs[("seq", "pallas")].global_params,
                 cnn_runs[("bat", "pallas")].global_params)
    assert d < ATOL, d
    hs = cnn_runs[("seq", "pallas")].history
    hb = cnn_runs[("bat", "pallas")].history
    for he, hbb in zip(hs, hb):
        np.testing.assert_allclose(he["ratios"], hbb["ratios"], atol=1e-6)
        assert abs(he["acc"] - hbb["acc"]) < 1e-4


def test_lm_pallas_matches_reference(lm_runs):
    """Dense-LM family: flash-attention + masked-MLP kernels reproduce the
    reference trajectory through scan-over-layers + remat + vmap."""
    d = _maxdiff(lm_runs[("seq", "reference")].global_params,
                 lm_runs[("seq", "pallas")].global_params)
    assert d < ATOL, d


def test_lm_batched_pallas_matches_sequential(lm_runs):
    d = _maxdiff(lm_runs[("seq", "pallas")].global_params,
                 lm_runs[("bat", "pallas")].global_params)
    assert d < ATOL, d


def test_sharded_engine_accepts_pallas():
    """ShardedFLRun (shard_map round program) runs the pallas substrate and
    replays the sequential trajectory on the host's default mesh."""
    from repro.federated import FLRun
    from repro.federated.runtime import ShardedFLRun
    setting = _cnn_setting()
    seq = _run(setting, FLRun, "pallas", rounds=2)
    sh = _run(setting, ShardedFLRun, "pallas", rounds=2)
    assert _maxdiff(seq.global_params, sh.global_params) < ATOL


def test_async_engine_accepts_pallas():
    """The bucketed async engine (full-model asyn training through the
    kernels at P=1) replays the sequential event loop."""
    from repro.configs import HeliosConfig
    from repro.federated import AsyncFLRun, FLRun, make_fleet, setup_clients

    cfg, train, test, parts = _cnn_setting()
    hcfg = HeliosConfig(mask_block=16)

    def mk(cls):
        clients = setup_clients(make_fleet(2, 2), parts, hcfg)
        return cls(cfg, hcfg, "asyn", clients, train, test, local_steps=1,
                   batch_size=4, lr=0.05, seed=0, eval_batch=48,
                   kernels="pallas")

    seq, buck = mk(FLRun), mk(AsyncFLRun)
    seq.run_async(8, eval_every=0)
    buck.run_async(8, eval_every=0)
    assert seq.events_processed == buck.events_processed
    assert _maxdiff(seq.global_params, buck.global_params) < ATOL


# ---------------------------------------------------------------------------
# (c) hypothesis properties for block_align_mask
# ---------------------------------------------------------------------------

try:                                  # optional dev dependency — only the
    from hypothesis import given, settings          # part (c) properties
    from hypothesis import strategies as st         # skip without it
    HAVE_HYPOTHESIS = True
except ImportError:                   # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _mask_strat = st.lists(st.booleans(), min_size=1, max_size=96).map(
        lambda bits: jnp.asarray(np.asarray(bits, np.float32)))
    _block_strat = st.integers(1, 64)

    @settings(max_examples=40, deadline=None)
    @given(_mask_strat, _block_strat)
    def test_block_align_idempotent(m, block):
        once = ops.block_align_mask(m, block)
        twice = ops.block_align_mask(once, block)
        np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))

    @settings(max_examples=40, deadline=None)
    @given(_mask_strat, _block_strat)
    def test_block_align_superset(m, block):
        out = ops.block_align_mask(m, block)
        assert np.all(np.asarray(out) >= np.asarray(m))
        assert set(np.unique(np.asarray(out))) <= {0.0, 1.0}

    @settings(max_examples=40, deadline=None)
    @given(_mask_strat, _block_strat)
    def test_block_align_block_constant(m, block):
        """Every block of the PADDED output is all-0 or all-1 — exactly the
        structure the kernels' per-block alive flags rely on."""
        out = np.asarray(ops.block_align_mask(m, block))
        n = out.shape[-1]
        pad = (-n) % block
        padded = np.pad(out, (0, pad))
        blocks = padded.reshape(-1, block)
        assert np.all((blocks.max(1) == blocks.min(1)) | (blocks.max(1) == 1))
        # stronger: within a block all entries equal UNLESS the block is the
        # ragged tail block (padding zeros), whose REAL entries are all 1
        for b in blocks[:-1] if pad else blocks:
            assert b.max() == b.min()
else:                                  # pragma: no cover
    @pytest.mark.skip(reason="optional dev dependency: hypothesis not "
                             "installed")
    def test_block_align_properties():
        pass
