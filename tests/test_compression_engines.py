"""The compression seam across engines.

Pins (a) ``compression="none"`` to today's trajectories (the knob is a
pure no-op), (b) the lossy modes to ONE trajectory across seq, batched,
sharded and bucketed-async engines (the codec + error feedback + lossy
ring are execution-layout-invariant), (c) the >= 10x topk uplink
reduction the ISSUE requires, and (d) the host-side error store's
lazy growth + the compile budgets under contracts.
"""
import os

if os.environ.get("REPRO_HOST_DEVICES") and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_HOST_DEVICES"])

import jax
import numpy as np
import pytest

from repro.analysis import contracts as CT
from repro.configs import CNNS, HeliosConfig, reduced
from repro.core import aggregation as AG
from repro.data.federated import partition_iid
from repro.data.synthetic import class_gaussian_images
from repro.federated import (AsyncFLRun, BatchedFLRun, FLRun, ShardedFLRun,
                             make_fleet, setup_clients)

LOSSY = ("topk", "quant", "delta")


@pytest.fixture(scope="module")
def setting():
    cfg = reduced(CNNS["lenet"])
    imgs, labels = class_gaussian_images(400, cfg.image_size,
                                         cfg.in_channels, cfg.num_classes,
                                         seed=0)
    ti, tl = class_gaussian_images(64, cfg.image_size, cfg.in_channels,
                                   cfg.num_classes, seed=9)
    parts = partition_iid(len(labels), 8, seed=0)
    return cfg, {"images": imgs, "labels": labels}, \
        {"images": ti, "labels": tl}, parts


def _make(setting, cls, scheme, **kw):
    cfg, train, test, parts = setting
    hcfg = HeliosConfig()
    clients = setup_clients(make_fleet(4, 4), parts, hcfg)
    return cls(cfg, hcfg, scheme, clients, train, test,
               local_steps=1, batch_size=8, lr=0.1, seed=0, eval_batch=64,
               **kw)


def _diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                   - np.asarray(y, np.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# none mode is a no-op; lossy modes are one trajectory across engines
# ---------------------------------------------------------------------------


def test_none_mode_is_default_noop(setting):
    """compression='none' produces bit-identical params, history and
    uplink accounting to not passing the knob at all."""
    a = _make(setting, FLRun, "helios")
    a.run_sync(2, eval_every=0)
    b = _make(setting, FLRun, "helios", compression="none")
    b.run_sync(2, eval_every=0)
    assert _diff(a.global_params, b.global_params) == 0.0
    assert a.uplink_bytes() == b.uplink_bytes() > 0


@pytest.mark.parametrize("mode", LOSSY)
def test_sync_cross_engine_wall(setting, mode):
    """seq <-> batched <-> sharded, same lossy mode: one trajectory (the
    codec runs inside each engine's program layout) and byte-identical
    uplink accounting."""
    runs = []
    for cls in (FLRun, BatchedFLRun, ShardedFLRun):
        r = _make(setting, cls, "helios", compression=mode)
        r.run_sync(3, eval_every=0)
        runs.append(r)
    seq, bat, sh = runs
    assert _diff(seq.global_params, bat.global_params) < 1e-4
    assert _diff(seq.global_params, sh.global_params) < 1e-4
    assert seq.uplink_updates == bat.uplink_updates == sh.uplink_updates
    b = [r.uplink_bytes() for r in runs]
    assert abs(b[0] - b[1]) < 1e-3 and abs(b[0] - b[2]) < 1e-3, b


@pytest.mark.parametrize("mode", LOSSY)
def test_sync_cross_engine_wall_sampled(setting, mode):
    """Partial participation exercises the per-cohort error-row gather /
    scatter path (row identity keyed by cid, stable across draws)."""
    seq = _make(setting, FLRun, "helios", compression=mode,
                participation=4)
    seq.run_sync(3, eval_every=0)
    bat = _make(setting, BatchedFLRun, "helios", compression=mode,
                participation=4)
    bat.run_sync(3, eval_every=0)
    assert seq.cohort_log == bat.cohort_log
    assert _diff(seq.global_params, bat.global_params) < 1e-4
    assert abs(seq.uplink_bytes() - bat.uplink_bytes()) < 1e-3


@pytest.mark.parametrize("mode", LOSSY)
@pytest.mark.parametrize("scheme", ["asyn", "afo"])
def test_async_cross_engine_wall(setting, scheme, mode):
    """Sequential run_async <-> bucketed AsyncFLRun under compression:
    same events, same trajectory (the bucketed lossy ring's write-time
    codes decode to exactly what the sequential reference recomputes at
    read time), same bytes."""
    seq = _make(setting, FLRun, scheme, compression=mode, comp_fresh=2)
    seq.run_async(12, eval_every=0, snapshot_cap=16)
    buc = _make(setting, AsyncFLRun, scheme, compression=mode,
                comp_fresh=2)
    buc.run_async(12, eval_every=0, snapshot_cap=16)
    assert seq.events_processed == buc.events_processed
    assert seq.agg_counter == buc.agg_counter
    assert _diff(seq.global_params, buc.global_params) < 1e-4
    assert abs(seq.uplink_bytes() - buc.uplink_bytes()) < 1e-3


# ---------------------------------------------------------------------------
# DGC-style compression warmup (comp_warmup)
# ---------------------------------------------------------------------------


def test_comp_warmup_covering_run_is_dense_noop(setting):
    """warmup >= rounds: every round runs the exact compression='none'
    program — bit-identical params AND byte-identical (dense) uplink."""
    a = _make(setting, BatchedFLRun, "helios", compression="topk",
              comp_warmup=3)
    a.run_sync(3, eval_every=0)
    b = _make(setting, BatchedFLRun, "helios", compression="none")
    b.run_sync(3, eval_every=0)
    assert _diff(a.global_params, b.global_params) == 0.0
    assert a.uplink_bytes() == b.uplink_bytes()
    assert a.uplink_dense_updates == a.uplink_updates


def test_comp_warmup_cross_engine_wall(setting):
    """Mid-run codec switch-on is still one trajectory across the sync
    engines, with split dense/compressed accounting agreeing byte-for-
    byte — and the phase split costs exactly one extra cached program."""
    runs = []
    for cls in (FLRun, BatchedFLRun, ShardedFLRun):
        r = _make(setting, cls, "helios", compression="topk",
                  comp_warmup=1)
        r.run_sync(3, eval_every=0)
        runs.append(r)
    seq, bat, sh = runs
    assert _diff(seq.global_params, bat.global_params) < 1e-4
    assert _diff(seq.global_params, sh.global_params) < 1e-4
    assert seq.uplink_dense_updates == bat.uplink_dense_updates \
        == sh.uplink_dense_updates == len(seq.clients)
    b = [r.uplink_bytes() for r in runs]
    assert abs(b[0] - b[1]) < 1e-3 and abs(b[0] - b[2]) < 1e-3, b
    # one program per (shape, codec-phase) key, not a retrace
    assert len(bat._round_cache) == 2


def test_comp_warmup_validation(setting):
    with pytest.raises(ValueError):
        _make(setting, FLRun, "helios", compression="topk", comp_warmup=-1)


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["topk", "delta"])
def test_comp_warmup_closes_early_round_gap(setting, mode):
    """The knob's reason to exist: a few dense warmup rounds recover part
    of the lossy modes' early-round accuracy gap vs dense (DGC's
    observation), at an uplink cost strictly between always-compressed
    and always-dense.  Values pinned at seed 0 over 12 rounds."""
    accs, bytes_ = {}, {}
    for name, kw in (("none", {}), ("plain", dict(compression=mode)),
                     ("warm", dict(compression=mode, comp_warmup=4))):
        r = _make(setting, BatchedFLRun, "helios", **kw)
        h = r.run_sync(12, eval_every=12)
        accs[name], bytes_[name] = h[-1]["acc"], r.uplink_bytes()
    gap_plain = accs["plain"] - accs["none"]
    gap_warm = accs["warm"] - accs["none"]
    assert gap_plain < -0.05, accs            # the gap warmup exists to fix
    assert gap_warm > gap_plain, accs         # ...and warmup closes it
    assert bytes_["plain"] < bytes_["warm"] < bytes_["none"]


# ---------------------------------------------------------------------------
# the numbers the ISSUE requires
# ---------------------------------------------------------------------------


def test_topk_uplink_reduction_at_least_10x(setting):
    dense = _make(setting, BatchedFLRun, "helios")
    dense.run_sync(2, eval_every=0)
    topk = _make(setting, BatchedFLRun, "helios", compression="topk",
                 comp_frac=0.05)
    topk.run_sync(2, eval_every=0)
    assert dense.uplink_bytes() / topk.uplink_bytes() >= 10.0


def test_lossy_ring_smaller_than_fp32(setting):
    cfg, train, test, parts = setting
    seq = _make(setting, FLRun, "afo")
    fp = AG.SnapshotRing(seq.global_params, 64, 8)
    for mode in ("quant", "delta"):
        lossy = AG.SnapshotRing(seq.global_params, 64, 8, mode=mode,
                                bits=8, fresh_window=2)
        assert lossy.nbytes() < fp.nbytes() / 2, mode
        # slot 0 decodes within the quantization bound at seed
        base = lossy.read(0, stale=99)
        err = _diff(base, seq.global_params)
        assert err < 0.05, (mode, err)
        # ...and exactly through the fresh row inside the window
        assert _diff(lossy.read(0, stale=0), seq.global_params) == 0.0


def test_error_store_grows_with_participation_not_population(setting):
    run = _make(setting, BatchedFLRun, "helios", compression="topk",
                participation=2)
    run.run_sync(3, eval_every=0)
    touched = run._err_store.touched()
    seen = {i for cohort in run.cohort_log for i in cohort}
    assert touched == len({run.clients[i].cid for i in seen})
    assert touched <= 6 < len(run.clients) + 1
    assert run._err_store.nbytes() > 0


def test_bad_mode_and_fresh_window_rejected(setting):
    with pytest.raises(ValueError):
        _make(setting, FLRun, "helios", compression="gzip")
    with pytest.raises(ValueError):
        _make(setting, FLRun, "helios", compression="quant", comp_fresh=0)


# ---------------------------------------------------------------------------
# contracts: no stray host syncs, compile budgets still hold
# ---------------------------------------------------------------------------


def test_compressed_engines_pass_contracts(setting):
    """Batched sync + bucketed async under REPRO_CONTRACTS: the error-row
    gather/scatter is an EXPECTED transfer, everything else stays on
    device, and the per-shape compile budget still holds (the codec adds
    no retraces)."""
    with CT.override(True):
        bat = _make(setting, BatchedFLRun, "helios", compression="delta",
                    participation=4)
        bat.run_sync(3, eval_every=0)
        CT.check_compile_budget(bat, tag="test.compressed.batched")
        buc = _make(setting, AsyncFLRun, "afo", compression="quant",
                    comp_fresh=2)
        buc.run_async(8, eval_every=0, snapshot_cap=16)
        CT.check_compile_budget(buc, tag="test.compressed.bucketed")
    assert all(v == 1 for v in buc.bucket_programs().values()), \
        buc.bucket_programs()
