"""Async event subsystem: the sequential↔bucketed equivalence wall, the
deterministic virtual clock, snapshot-ring invariants, bucket mixing, and
the lazy non-IID partitions.

The bucketed engine (AsyncFLRun) must be a pure execution-layout change:
for a fixed seed it replays the sequential ``FLRun.run_async`` trajectory
(same event order, same batches, same snapshots/anchors, same
staleness-discounted mixing) up to vmapped-reduction float error — with or
without arrival jitter and dropout, on every engine class.
"""
import os

# the multi-device CI job forces a host device count before jax initializes
if os.environ.get("REPRO_HOST_DEVICES") and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_HOST_DEVICES"])

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts as CT
from repro.configs import CNNS, HeliosConfig, reduced
from repro.core import aggregation as AG
from repro.data.federated import (partition_by_topic, partition_by_topic_lazy,
                                  partition_iid, partition_noniid,
                                  partition_noniid_lazy)
from repro.data.synthetic import class_gaussian_images
from repro.federated import (AsyncFLRun, BatchedFLRun, BernoulliDropout,
                             FLRun, JitteredArrival, ShardedFLRun, SimClock,
                             make_fleet, setup_clients)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAVE_HYP = True
except ImportError:                                     # pragma: no cover
    HAVE_HYP = False

needs_hyp = pytest.mark.skipif(not HAVE_HYP, reason="hypothesis missing")


@pytest.fixture(scope="module")
def setting():
    cfg = reduced(CNNS["lenet"])
    imgs, labels = class_gaussian_images(800, cfg.image_size,
                                         cfg.in_channels, cfg.num_classes,
                                         seed=0)
    ti, tl = class_gaussian_images(96, cfg.image_size, cfg.in_channels,
                                   cfg.num_classes, seed=9)
    parts = partition_iid(len(labels), 8, seed=0)
    return cfg, {"images": imgs, "labels": labels}, \
        {"images": ti, "labels": tl}, parts


def _make(setting, cls, scheme, **kw):
    cfg, train, test, parts = setting
    hcfg = HeliosConfig()
    clients = setup_clients(make_fleet(4, 4), parts, hcfg)
    return cls(cfg, hcfg, scheme, clients, train, test,
               local_steps=1, batch_size=8, lr=0.1, seed=0, eval_batch=96,
               **kw)


def _max_param_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                   - np.asarray(y, np.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# the equivalence wall: sequential run_async <-> bucketed AsyncFLRun
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", ["asyn", "afo"])
def test_async_equivalence_wall(setting, scheme):
    """Fixed seed, >= 64 events: the bucketed engine reproduces the
    sequential global-param trajectory, processes the identical event set,
    and compiles exactly one program per bucket-shape signature."""
    seq = _make(setting, FLRun, scheme)
    buck = _make(setting, AsyncFLRun, scheme)
    seq.run_async(52, eval_every=0)
    buck.run_async(52, eval_every=0)
    assert seq.events_processed >= 64
    assert buck.events_processed == seq.events_processed
    assert buck.agg_counter == seq.agg_counter
    assert _max_param_diff(seq.global_params, buck.global_params) < 1e-5
    # ...and each client re-anchored to the same aggregation step
    for cs, cb in zip(seq.clients, buck.clients):
        assert cs.staleness_anchor == cb.staleness_anchor
    # shape-stable compilation: one program per padded bucket size —
    # asserted through the contracts API (the library-level budget)
    rep = CT.compile_report(buck)
    assert rep.get("bucket"), rep               # buckets actually compiled
    with CT.override(True):
        CT.check_compile_budget(buck)
    assert max(buck.bucket_sizes) > 1          # ties actually bucketed
    assert buck.snapshot_anchor_misses == 0
    assert buck.snapshot_peak <= 64 + len(buck.clients) + 2


def test_async_equivalence_with_jitter_and_dropout(setting):
    """Pluggable arrival/dropout processes draw once per event in pop order
    on both engines, so a jittered lossy fleet still replays identically."""
    runs = []
    for cls in (FLRun, AsyncFLRun):
        r = _make(setting, cls, "afo",
                  arrival=JitteredArrival(sigma=0.2),
                  dropout=BernoulliDropout(p=0.25, penalty=0.5))
        r.run_async(24, eval_every=0)
        runs.append(r)
    seq, buck = runs
    assert seq.events_processed == buck.events_processed
    assert seq.events_dropped == buck.events_dropped > 0
    assert _max_param_diff(seq.global_params, buck.global_params) < 1e-5


def test_bucketed_async_on_every_engine(setting):
    """BatchedFLRun / ShardedFLRun inherit the bucketed async engine (no
    sequential fallback) and stay on the reference trajectory."""
    ref = _make(setting, FLRun, "afo")
    ref.run_async(16, eval_every=0)
    for cls in (BatchedFLRun, ShardedFLRun):
        run = _make(setting, cls, "afo")
        hist = run.run_async(16, eval_every=4)
        assert run.events_processed == ref.events_processed
        assert _max_param_diff(ref.global_params, run.global_params) < 1e-5
        assert hist and all("acc" in h and "bucket" in h for h in hist)


def test_soft_scheme_async_delegates_to_sequential(setting):
    """The bucket program trains full models (the asyn/afo semantics); a
    soft-training scheme must fall through to the sequential event loop —
    on every engine class — instead of silently dropping its masks."""
    # 12 capable completions = 3 virtual ticks: the 2.5x/2.9x stragglers
    # complete (and soft-train) inside the window
    ref = _make(setting, FLRun, "helios")
    ref.run_async(12, eval_every=0)
    for cls in (AsyncFLRun, BatchedFLRun):
        run = _make(setting, cls, "helios")
        run.run_async(12, eval_every=0)
        assert run.events_processed == ref.events_processed
        assert _max_param_diff(ref.global_params, run.global_params) < 1e-5
        # ...and the stragglers' soft-training state actually evolved
        assert any(int(np.asarray(c.helios_state["cycle"])) > 0
                   for c in run.clients if c.is_straggler)


# ---------------------------------------------------------------------------
# deterministic virtual clock
# ---------------------------------------------------------------------------


def test_equal_time_events_pop_in_cid_order():
    """Regression: tie-breaking used to be insertion order (unspecified
    across engines); the heap is now keyed (time, cid)."""
    clk = SimClock()
    for cid in [5, 1, 9, 3, 7]:
        clk.schedule(2.0, cid)
    for cid in [4, 0]:
        clk.schedule(1.0, cid)
    assert [clk.pop(), clk.pop()] == [0, 4]
    assert [e.cid for e in clk.pop_bucket()] == [1, 3, 5, 7, 9]
    assert clk.now == 2.0 and clk.empty()


def test_pop_bucket_horizon_and_cap():
    clk = SimClock()
    for cid, t in ((0, 1.0), (1, 1.0), (2, 1.4), (3, 2.0)):
        clk.schedule(t, cid)
    evs = clk.pop_bucket(horizon=0.5)
    assert [e.cid for e in evs] == [0, 1, 2]    # 2.0 is past the horizon
    assert clk.pop_bucket() == [type(evs[0])(2.0, 3)]
    # max_size caps a tie-group without losing its tail
    for cid in range(5):
        clk.schedule(1.0, cid)
    assert [e.cid for e in clk.pop_bucket(max_size=2)] == [0, 1]
    assert [e.cid for e in clk.pop_bucket()] == [2, 3, 4]


def test_schedule_at_keeps_now_monotone():
    clk = SimClock()
    clk.schedule(2.0, 0)
    clk.pop()
    clk.schedule_at(1.0, 1)                     # bucket-truncation reinsert
    assert clk.pop() == 1
    assert clk.now == 2.0                       # never rewinds


# ---------------------------------------------------------------------------
# snapshot ring buffer + bucket mixing
# ---------------------------------------------------------------------------


def test_ring_alloc_raises_when_all_slots_anchored():
    alloc = AG.RingAllocator(3)                 # 2 data slots + scratch
    alloc.seed(0)
    alloc.retain(0)
    alloc.alloc(1)
    alloc.retain(1)
    with pytest.raises(RuntimeError):
        alloc.alloc(2)


def test_mix_bucket_matches_sequential_mix():
    key = jax.random.PRNGKey(0)
    g = {"a": jax.random.normal(key, (3, 4)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (5,))}
    stacked = jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, 2),
                                    (3,) + x.shape), g)
    ws = [0.5, 0.25, 0.0]
    ref = g
    for i, w in enumerate(ws):
        ref = AG.mix(ref, jax.tree.map(lambda x: x[i], stacked), w)
    out = AG.mix_bucket(g, stacked, jnp.asarray(ws, jnp.float32))
    assert _max_param_diff(ref, out) < 1e-6


def test_mix_bucket_ring_snapshots_every_intermediate():
    key = jax.random.PRNGKey(7)
    g = {"w": jax.random.normal(key, (4,))}
    stacked = {"w": jax.random.normal(jax.random.fold_in(key, 1), (2, 4))}
    ring = {"w": jnp.zeros((4, 4)).at[0].set(g["w"])}
    ws = jnp.asarray([0.5, 0.25], jnp.float32)
    out_g, out_ring = AG.mix_bucket_ring(g, ring, jnp.asarray([1, 2]),
                                         stacked, ws)
    ref = g
    for i in range(2):
        ref = AG.mix(ref, {"w": stacked["w"][i]}, float(ws[i]))
        np.testing.assert_allclose(out_ring["w"][i + 1], ref["w"],
                                   atol=1e-6)
    np.testing.assert_allclose(out_g["w"], ref["w"], atol=1e-6)
    np.testing.assert_allclose(out_ring["w"][0], g["w"])   # untouched row


if HAVE_HYP:

    @needs_hyp
    @settings(deadline=None, max_examples=40)
    @given(hst.integers(0, 10 ** 6), hst.floats(0.01, 4.0))
    def test_staleness_weight_properties(s, a):
        """(0, 1], monotone non-increasing in staleness, and the traced
        vector form agrees with the scalar reference."""
        w = AG.staleness_weight(s, a)
        assert 0.0 < w <= 1.0
        assert AG.staleness_weight(s + 1, a) <= w
        vec = AG.staleness_weights(jnp.asarray([s, s + 1], jnp.float32), a)
        np.testing.assert_allclose(np.asarray(vec),
                                   [AG.staleness_weight(s, a),
                                    AG.staleness_weight(s + 1, a)],
                                   rtol=2e-5)

    @needs_hyp
    @settings(deadline=None, max_examples=60)
    @given(hst.data())
    def test_ring_allocator_never_evicts_live_anchor(data):
        """Random completion-event sequences: a slot some client still
        reads through is never reallocated, every live anchor stays
        resolvable, and the ring stays bounded by cap + clients."""
        n_clients = data.draw(hst.integers(1, 6), label="clients")
        cap = data.draw(hst.integers(1, 4), label="cap")
        alloc = AG.RingAllocator(max(cap, n_clients + 1) + 1)
        alloc.seed(0)
        anchor = {cid: 0 for cid in range(n_clients)}
        for _ in range(n_clients):
            alloc.retain(0)
        agg = 0
        for _ in range(data.draw(hst.integers(1, 48), label="events")):
            cid = data.draw(hst.integers(0, n_clients - 1), label="cid")
            live_others = {a for c2, a in anchor.items() if c2 != cid}
            alloc.slot_of(anchor[cid])          # must never KeyError
            agg += 1
            alloc.release(anchor[cid])
            s_new = alloc.alloc(agg)
            assert s_new != alloc.scratch
            assert all(alloc.slot_of(a) != s_new for a in live_others)
            alloc.retain(agg)
            anchor[cid] = agg
        assert alloc.anchor_misses == 0
        assert alloc.slots <= cap + n_clients + 2
        assert alloc.live_slots() <= n_clients


# ---------------------------------------------------------------------------
# lazy non-IID partitions
# ---------------------------------------------------------------------------


def test_lazy_noniid_index_equal():
    rng = np.random.default_rng(3)
    labels = rng.integers(0, 10, size=503)
    eager = partition_noniid(labels, 7, shards_per_client=3, seed=5)
    lazy = partition_noniid_lazy(labels, 7, shards_per_client=3, seed=5)
    assert len(lazy) == len(eager) == 7
    for a, b in zip(eager, (lazy[i] for i in range(7))):
        assert len(b) == len(a)
        np.testing.assert_array_equal(a, np.asarray(b))


def test_lazy_by_topic_index_equal():
    rng = np.random.default_rng(4)
    topics = rng.integers(0, 8, size=257)
    eager = partition_by_topic(topics, 5, topics_per_client=2, seed=1)
    lazy = partition_by_topic_lazy(topics, 5, topics_per_client=2, seed=1)
    for a, b in zip(eager, (lazy[i] for i in range(5))):
        np.testing.assert_array_equal(a, np.asarray(b))
