"""Numerical test of the fused datacenter FL round (pods = clients).

Runs make_fl_round_step on CPU with 2 stacked clients: after a round every
client must hold the SAME aggregated model (broadcast back), the loss must
be finite, and with Helios disabled the aggregation must equal the uniform
mean of the per-client locally-trained params.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, HeliosConfig, TrainConfig, reduced
from repro.launch import steps as S
from repro.models import default_runtime


def _stack_state(base, n):
    return jax.tree.map(lambda t: jnp.stack([t] * n), base)


def test_fl_round_aggregates_and_broadcasts():
    cfg = reduced(ARCHS["deepseek-7b"])
    hcfg = HeliosConfig(enabled=False)
    tcfg = TrainConfig(learning_rate=1e-2, total_steps=10, microbatches=1,
                       warmup_steps=0)
    rt = default_runtime(cfg)
    n_clients, local_steps = 2, 3

    step = S.make_fl_round_step(cfg, hcfg, tcfg, rt, n_clients)
    base = S.init_train_state(jax.random.PRNGKey(0), cfg, hcfg, tcfg)
    state = {"params": _stack_state(base["params"], n_clients),
             "opt": _stack_state(base["opt"], n_clients),
             "step": base["step"],
             "helios": _stack_state(base["helios"], n_clients)}

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (n_clients, local_steps, 2, 32), 0, cfg.padded_vocab)}

    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    np.testing.assert_allclose(np.asarray(metrics["alpha"]), [0.5, 0.5])

    # every client restarts from the same aggregated model
    for leaf in jax.tree.leaves(new_state["params"]):
        np.testing.assert_array_equal(np.asarray(leaf[0]),
                                      np.asarray(leaf[1]))

    # params actually moved
    moved = sum(float(jnp.abs(a[0] - b).sum()) for a, b in zip(
        jax.tree.leaves(new_state["params"]),
        jax.tree.leaves(base["params"])))
    assert moved > 0
