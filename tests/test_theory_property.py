"""Hypothesis property tests for Prop. 2 (gradient-variance bound) and the
system's selection invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency: property tests need it")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import selection as S
from repro.core import theory as T

_gvec = st.lists(st.floats(-5, 5, allow_nan=False, width=32),
                 min_size=8, max_size=64).map(np.asarray)


@settings(max_examples=30, deadline=None)
@given(_gvec, st.integers(1, 8))
def test_st_estimator_unbiased(g, v):
    """E[ST(g)] = g (Eq. 5): Monte-Carlo mean approaches g."""
    g = jnp.asarray(g, jnp.float32)
    v = min(v, g.shape[0])
    p = T.wangni_probabilities(g, v)
    keys = jax.random.split(jax.random.PRNGKey(0), 400)
    draws = jax.vmap(lambda k: T.st_estimate(g, p, k))(keys)
    mc = jnp.mean(draws, axis=0)
    scale = float(jnp.max(jnp.abs(g))) + 1.0
    assert float(jnp.max(jnp.abs(mc - g))) < 0.6 * scale


@settings(max_examples=50, deadline=None)
@given(_gvec, st.integers(1, 16))
def test_probabilities_valid(g, v):
    g = jnp.asarray(g, jnp.float32)
    v = min(v, g.shape[0])
    p = T.wangni_probabilities(g, v)
    assert float(p.min()) > 0.0 and float(p.max()) <= 1.0
    # the v coords with p=1 have |g| >= every non-kept coord's |g|
    # (tie-robust: argsort tie order may differ between np and jnp)
    pn = np.asarray(p)
    gn = np.abs(np.asarray(g))
    kept = pn >= 1.0
    assert kept.sum() >= v
    if (~kept).any() and kept.any():
        assert gn[kept].min() >= gn[~kept].max() - 1e-6


@settings(max_examples=50, deadline=None)
@given(_gvec, st.integers(1, 8), st.floats(0.1, 1.0))
def test_eq9_sparsity_bound(g, v, rho):
    """E||ST(g)||_0 <= (1 + rho) v (Eq. 9)."""
    g = jnp.asarray(g, jnp.float32)
    v = min(v, g.shape[0])
    sparsity, bound = T.check_convergence_condition(g, v, rho)
    assert float(sparsity) <= float(bound) + 1e-4


@settings(max_examples=50, deadline=None)
@given(_gvec, st.integers(1, 8))
def test_keeping_more_top_coords_reduces_variance(g, v):
    """Monotonicity: larger v (more p=1 coords) => smaller 2nd moment."""
    g = jnp.asarray(g, jnp.float32)
    v = min(v, g.shape[0] - 1)
    p1 = T.wangni_probabilities(g, v)
    p2 = T.wangni_probabilities(g, v + 1)
    m1 = float(T.st_second_moment(g, p1))
    m2 = float(T.st_second_moment(g, p2))
    assert m2 <= m1 + 1e-3 * (1 + m1)


@settings(max_examples=40, deadline=None)
@given(st.integers(8, 128), st.floats(0.1, 1.0), st.integers(0, 10 ** 6))
def test_selection_respects_volume(n, vol, seed):
    """select_masks picks round(P*n) (clipped to >=1) units per row."""
    key = jax.random.PRNGKey(seed)
    scores = {"u": jax.random.uniform(key, (1, n))}
    forced = {"u": jnp.zeros((1, n), bool)}
    masks = S.select_masks(scores, forced, jnp.asarray(vol), 0.1,
                           jax.random.fold_in(key, 1))
    count = int(masks["u"].sum())
    expect = max(1, int(round(vol * n)))
    assert abs(count - expect) <= 1, (count, expect, n, vol)


@settings(max_examples=30, deadline=None)
@given(st.integers(16, 64), st.integers(0, 10 ** 6))
def test_full_volume_selects_everything(n, seed):
    scores = {"u": jax.random.uniform(jax.random.PRNGKey(seed), (2, n))}
    forced = {"u": jnp.zeros((2, n), bool)}
    masks = S.select_masks(scores, forced, jnp.asarray(1.0), 0.1,
                           jax.random.PRNGKey(0))
    assert float(masks["u"].min()) == 1.0
