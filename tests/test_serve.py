"""Serve-while-you-train: checkpoint hardening + lock-free hot-swap serving.

Pins the checkpoint-layer bugfix sweep (keep<=0 GC, NamedTuple restore
fidelity, clean empty-dir errors, crash-leftover tmp sweep), the
kill-mid-write recovery story (a truncated ``.tmp`` is unobservable: the
older complete step restores and the ServeLoop never serves a partial
snapshot), the eval-gated promotion rule (a regressing snapshot is NOT
promoted and the decision lands in the obs run log), the lock-free swap
(one compiled prefill/decode program across swaps), the engines'
round-end publish hook, and the ``--gen 1`` CLI edge case.
"""
import collections
import os
import threading

import jax
import numpy as np
import pytest

from repro import checkpoint as CKPT
from repro.configs import ARCHS, CNNS, HeliosConfig, reduced
from repro.data.federated import partition_noniid
from repro.data.synthetic import class_gaussian_images, markov_tokens
from repro.federated import FLRun, make_fleet, setup_clients
from repro.launch import serve as SV
from repro.models import init_params
from repro.obs import recorder as OBS


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(3, 4)).astype(np.float32),
            "b": rng.normal(size=(4,)).astype(np.float32)}


# ---------------------------------------------------------------------------
# checkpoint-layer bugfix sweep
# ---------------------------------------------------------------------------


def test_save_keep_zero_raises(tmp_path):
    """keep=0 used to make steps[:-0] the empty slice — GC silently kept
    everything; now it fails loudly."""
    with pytest.raises(ValueError, match="keep must be >= 1"):
        CKPT.save(str(tmp_path), 1, _tree(), keep=0)


def test_gc_keeps_exactly_n(tmp_path):
    for s in range(5):
        CKPT.save(str(tmp_path), s, _tree(s), keep=2)
    kept = sorted(f for f in os.listdir(tmp_path) if f.endswith(".zst"))
    assert kept == ["ckpt_3.msgpack.zst", "ckpt_4.msgpack.zst"]


def test_gc_sweeps_stale_tmp(tmp_path):
    """A crash mid-write abandons a ``.tmp``; the next save's GC removes
    it instead of letting leftovers accumulate forever."""
    stale = tmp_path / "ckpt_7.msgpack.zst.tmp"
    stale.write_bytes(b"partial garbage from a dead writer")
    CKPT.save(str(tmp_path), 8, _tree(), keep=3)
    assert not stale.exists()
    assert CKPT.latest_step(str(tmp_path)) == 8


def test_restore_namedtuple_roundtrip(tmp_path):
    """NamedTuple containers (optimizer moments) must come back as the
    same pytree TYPE, not collapse to plain tuples."""
    Moments = collections.namedtuple("Moments", ["mu", "nu"])
    state = {"opt": Moments(mu=_tree(1), nu=_tree(2)),
             "steps": (np.int32(3), np.int32(4))}
    CKPT.save(str(tmp_path), 1, state)
    out, step = CKPT.restore(str(tmp_path), state)
    assert step == 1
    assert type(out["opt"]) is Moments
    assert type(out["steps"]) is tuple
    np.testing.assert_allclose(out["opt"].mu["w"], state["opt"].mu["w"])
    # pytree structure identical => jax.tree.map over both works
    jax.tree.map(np.subtract, out, state)


def test_metadata_and_restore_empty_dir_clean_error(tmp_path):
    """An empty directory raises the clean 'no checkpoints in' error,
    not a baffling ckpt_None.msgpack.zst FileNotFoundError."""
    for fn in (lambda: CKPT.metadata(str(tmp_path)),
               lambda: CKPT.restore(str(tmp_path), _tree())):
        with pytest.raises(FileNotFoundError, match="no checkpoints in"):
            fn()


def test_restore_ignores_truncated_tmp(tmp_path):
    """Kill-mid-write: a truncated ``.tmp`` next to an older complete
    checkpoint is unobservable — restore picks the older step."""
    CKPT.save(str(tmp_path), 1, _tree(1))
    blob = (tmp_path / "ckpt_1.msgpack.zst").read_bytes()
    (tmp_path / "ckpt_2.msgpack.zst.tmp").write_bytes(blob[:len(blob) // 3])
    assert CKPT.latest_step(str(tmp_path)) == 1
    out, step = CKPT.restore(str(tmp_path), _tree())
    assert step == 1
    np.testing.assert_allclose(out["w"], _tree(1)["w"])


# ---------------------------------------------------------------------------
# ServeLoop: promotion gate + lock-free hot swap
# ---------------------------------------------------------------------------


def _echo_request(params, batch):
    return jax.numpy.asarray(params["w"]).sum() + batch


def test_promotion_gate_rejects_regression(tmp_path):
    """The acceptance pin: a regressing snapshot is NOT promoted, the
    decision is recorded, and swap/staleness events land in the run log."""
    d = str(tmp_path)
    metrics = iter([1.0, 2.0, 0.9])        # good, regressed, recovered
    rec = OBS.Recorder(armed=True)
    loop = SV.ServeLoop(d, _tree(), request_fn=_echo_request,
                        eval_fn=lambda p: next(metrics),
                        higher_is_better=False, tol=0.1, recorder=rec)
    CKPT.save(d, 1, _tree(1), metadata={"round": 1})
    assert loop.poll() and loop.served_step == 1

    CKPT.save(d, 2, _tree(2), metadata={"round": 2})
    assert not loop.poll()                 # 2.0 > 1.0 + tol: rejected
    assert loop.served_step == 1 and loop.served_metric == 1.0
    assert not loop.poll()                 # decided once, not re-evaluated
    assert rec.count("serve_rejections") == 1

    CKPT.save(d, 3, _tree(3), metadata={"round": 3})
    assert loop.poll() and loop.served_step == 3
    assert rec.count("serve_swaps") == 2
    # the request path observes the staleness of what it serves
    loop.handle(0.0)
    kinds = [e["kind"] for e in rec.events]
    assert kinds.count("promotion") == 3 and kinds.count("swap") == 2
    promo = [e for e in rec.events if e["kind"] == "promotion"]
    assert [p["promoted"] for p in promo] == [True, False, True]
    swaps = [e for e in rec.events if e["kind"] == "swap"]
    assert all("staleness" in s for s in swaps)
    assert rec.hists["serve_staleness"] == [0]


def test_promotion_gate_higher_is_better(tmp_path):
    d = str(tmp_path)
    metrics = iter([0.8, 0.5])
    loop = SV.ServeLoop(d, _tree(), request_fn=_echo_request,
                        eval_fn=lambda p: next(metrics),
                        higher_is_better=True, tol=0.1)
    CKPT.save(d, 1, _tree(1))
    assert loop.poll()
    CKPT.save(d, 2, _tree(2))
    assert not loop.poll()                 # 0.5 < 0.8 - 0.1: rejected


def test_serve_before_any_snapshot_raises(tmp_path):
    loop = SV.ServeLoop(str(tmp_path), _tree(), request_fn=_echo_request)
    assert not loop.poll()
    with pytest.raises(RuntimeError, match="nothing promoted"):
        loop.handle(0.0)


def test_hot_swap_never_serves_partial_snapshot(tmp_path):
    """A truncated in-flight ``.tmp`` must be invisible to the poll path:
    the loop keeps serving the older complete step."""
    d = str(tmp_path)
    loop = SV.ServeLoop(d, _tree(), request_fn=_echo_request)
    CKPT.save(d, 1, _tree(1), metadata={"round": 1})
    assert loop.poll() and loop.served_step == 1
    blob = (tmp_path / "ckpt_1.msgpack.zst").read_bytes()
    (tmp_path / "ckpt_2.msgpack.zst.tmp").write_bytes(blob[: len(blob) // 3])
    assert not loop.poll()                 # tmp never matches the key re
    out = loop.handle(0.0)
    assert loop.served_step == 1
    np.testing.assert_allclose(np.asarray(out), _tree(1)["w"].sum(),
                               rtol=1e-6)


@pytest.fixture(scope="module")
def lm_serving():
    cfg = reduced(ARCHS["xlstm-125m"])
    srv = SV.GenerationServer(cfg, batch=2, prompt_len=8, gen=3)
    prompts = markov_tokens(2, 8, cfg.padded_vocab, seed=0)
    req = SV.serve_batch(cfg, prompts, np.random.default_rng(0))
    return cfg, srv, req


def test_hot_swap_lm_no_recompile(lm_serving, tmp_path):
    """Swapping published snapshots rebinds the params reference between
    jitted calls: ONE prefill + ONE decode program across every swap."""
    cfg, srv, req = lm_serving
    d = str(tmp_path)
    loop = SV.ServeLoop(d, init_params(jax.random.PRNGKey(0), cfg),
                        request_fn=srv)
    for step in (1, 2, 3):
        CKPT.save(d, step, init_params(jax.random.PRNGKey(step), cfg),
                  metadata={"round": step})
        assert loop.poll() and loop.served_step == step
        toks = loop.handle(req)
        assert toks.shape == (2, 3)
    assert srv.programs() == {"prefill": 1, "decode": 1}


def test_traffic_loop_serves_while_training(lm_serving, tmp_path):
    """serve_while_training overlaps a publisher thread with the traffic
    loop; the final poll picks up the last publish and the stats carry
    every per-request latency."""
    cfg, srv, req = lm_serving
    d = str(tmp_path)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rec = OBS.Recorder(armed=True)
    loop = SV.ServeLoop(d, params, request_fn=srv, recorder=rec)
    CKPT.save(d, 0, params, metadata={"round": 0})
    assert loop.poll()
    published = threading.Event()

    def train_fn():                         # stand-in publisher
        CKPT.save(d, 5, init_params(jax.random.PRNGKey(5), cfg),
                  metadata={"round": 5})
        published.wait(5.0)

    def make_batch(i):
        published.set()
        return req

    stats = SV.serve_while_training(
        train_fn, loop, SV.PoissonTraffic(rate_hz=500.0, seed=0),
        make_batch, min_requests=3)
    assert stats["requests"] >= 3
    assert len(stats["latency_ms"]) == stats["requests"]
    assert stats["requests_per_sec"] > 0
    assert loop.served_step == 5            # final poll saw the publish
    assert rec.count("serve_swaps") >= 2
    assert srv.programs() == {"prefill": 1, "decode": 1}


def test_traffic_training_exception_propagates(lm_serving, tmp_path):
    cfg, srv, req = lm_serving
    d = str(tmp_path)
    params = init_params(jax.random.PRNGKey(0), cfg)
    loop = SV.ServeLoop(d, params, request_fn=srv)
    CKPT.save(d, 0, params, metadata={"round": 0})
    assert loop.poll()

    def boom():
        raise RuntimeError("train thread died")

    with pytest.raises(RuntimeError, match="train thread died"):
        SV.serve_while_training(boom, loop,
                                SV.PoissonTraffic(rate_hz=500.0, seed=0),
                                lambda i: req, min_requests=1)


def test_poisson_schedule_deterministic():
    import itertools
    a = list(itertools.islice(SV.PoissonTraffic(50.0, seed=3).schedule(), 20))
    b = list(itertools.islice(SV.PoissonTraffic(50.0, seed=3).schedule(), 20))
    c = list(itertools.islice(SV.PoissonTraffic(50.0, seed=4).schedule(), 20))
    assert a == b and a != c
    assert all(x < y for x, y in zip(a, a[1:]))


# ---------------------------------------------------------------------------
# the engines' round-end publish hook
# ---------------------------------------------------------------------------


def test_publish_hook_round_end(tmp_path):
    """publish_dir + publish_every: atomic snapshots at round end, GC'd to
    publish_keep, metadata carrying round/sim_time/scheme, and the
    published params exactly the live global params."""
    cfg = reduced(CNNS["lenet"])
    imgs, labels = class_gaussian_images(400, cfg.image_size,
                                         cfg.in_channels, cfg.num_classes,
                                         seed=0)
    parts = partition_noniid(labels, 4, shards_per_client=4)
    clients = setup_clients(make_fleet(2, 2), parts, HeliosConfig())
    run = FLRun(cfg, HeliosConfig(), "helios", clients,
                {"images": imgs, "labels": labels},
                {"images": imgs[:64], "labels": labels[:64]},
                local_steps=1, lr=0.05, seed=0, eval_batch=64,
                publish_dir=str(tmp_path), publish_every=2,
                publish_keep=1)
    run.run_sync(4, eval_every=0)
    steps = sorted(int(f.split("_")[1].split(".")[0])
                   for f in os.listdir(tmp_path) if f.endswith(".zst"))
    assert steps == [4]                     # published at rounds 2,4; keep=1
    assert run.rec.count("published_snapshots") == 2
    meta = CKPT.metadata(str(tmp_path))
    assert meta["round"] == 4 and meta["scheme"] == "helios"
    assert meta["sim_time"] > 0
    out, step = CKPT.restore(str(tmp_path), run.global_params)
    assert step == 4
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(run.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_publish_every_validation():
    with pytest.raises(ValueError, match="publish_every"):
        cfg = reduced(CNNS["lenet"])
        imgs, labels = class_gaussian_images(64, cfg.image_size,
                                             cfg.in_channels,
                                             cfg.num_classes, seed=0)
        parts = partition_noniid(labels, 2, shards_per_client=2)
        clients = setup_clients(make_fleet(1, 1), parts, HeliosConfig())
        FLRun(cfg, HeliosConfig(), "helios", clients,
              {"images": imgs, "labels": labels},
              {"images": imgs, "labels": labels}, publish_every=0)


# ---------------------------------------------------------------------------
# CLI edge cases
# ---------------------------------------------------------------------------


def test_cli_gen_one_prefill_only(capsys):
    """--gen 1 decodes nothing: the tok/s figure is skipped, not a 0/0
    artifact, and the prompt's first token still comes back."""
    toks = SV.main(["--arch", "xlstm-125m", "--reduced", "--batch", "1",
                    "--prompt-len", "8", "--gen", "1"])
    assert toks.shape == (1, 1)
    out = capsys.readouterr().out
    assert "prefill-only" in out and "tok/s" not in out.split("skipped")[0]


def test_cli_serves_published_checkpoint(tmp_path, capsys):
    cfg = reduced(ARCHS["xlstm-125m"])
    CKPT.save(str(tmp_path), 9, init_params(jax.random.PRNGKey(1), cfg),
              metadata={"round": 9})
    toks = SV.main(["--arch", "xlstm-125m", "--reduced", "--batch", "1",
                    "--prompt-len", "8", "--gen", "2",
                    "--ckpt-dir", str(tmp_path)])
    assert toks.shape == (1, 2)
    assert "restored snapshot step 9" in capsys.readouterr().out
