"""Equivalence of the parallel (chunkwise) recurrence algorithms vs their
step-by-step oracles, and prefill+decode vs full-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKE_SHAPE, reduced
from repro.models import build, default_runtime, init_params, make_full_masks
from repro.models.ssm import ssd_chunked, ssd_recurrent_ref
from repro.models.xlstm import mlstm_chunkwise, mlstm_recurrent_ref


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_recurrent(chunk):
    key = jax.random.PRNGKey(0)
    b, s, nh, hd, ds = 2, 64, 3, 8, 4
    xh = jax.random.normal(key, (b, s, nh, hd))
    Bm = jax.random.normal(jax.random.fold_in(key, 1), (b, s, ds))
    Cm = jax.random.normal(jax.random.fold_in(key, 2), (b, s, ds))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3),
                                           (b, s, nh)))
    A = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 4), (nh,)))
    y_c, h_c = ssd_chunked(xh, Bm, Cm, dt, A, chunk=chunk)
    y_r, h_r = ssd_recurrent_ref(xh, Bm, Cm, dt, A)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_r),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [8, 32])
def test_mlstm_chunkwise_matches_recurrent(chunk):
    key = jax.random.PRNGKey(1)
    b, s, nh, hd = 2, 64, 2, 16
    q = jax.random.normal(key, (b, s, nh, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, nh, hd)) / 4
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, nh, hd))
    gi = jax.random.normal(jax.random.fold_in(key, 3), (b, s, nh))
    gf = jax.random.normal(jax.random.fold_in(key, 4), (b, s, nh)) + 2.0
    h_c, (C_c, n_c, m_c) = mlstm_chunkwise(q, k, v, gi, gf, chunk=chunk)
    h_r, (C_r, n_r, m_r) = mlstm_recurrent_ref(q, k, v, gi, gf)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(m_c), np.asarray(m_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(C_c), np.asarray(C_r),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["deepseek-7b", "deepseek-v2-236b",
                                  "xlstm-125m", "zamba2-1.2b",
                                  "seamless-m4t-large-v2"])
def test_prefill_plus_decode_matches_longer_prefill(arch):
    """Golden consistency: prefill(S) then decode(1 token) must produce the
    same final logits as prefill(S+1) over the extended prompt."""
    cfg = reduced(ARCHS[arch])
    api = build(cfg)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    rt = default_runtime(cfg, SMOKE_SHAPE)
    rt["attn_impl"] = "dense"
    masks = make_full_masks(cfg)
    b, s = 2, 17

    toks = jax.random.randint(jax.random.fold_in(key, 1), (b, s + 1), 0,
                              cfg.vocab_size)
    batch_s = {"tokens": toks[:, :s]}
    batch_s1 = {"tokens": toks}
    if cfg.family == "vlm":
        img = jax.random.normal(jax.random.fold_in(key, 2),
                                (b, cfg.num_image_tokens, cfg.d_model))
        batch_s["image_embeds"] = batch_s1["image_embeds"] = img
    if cfg.family == "encdec":
        enc = jax.random.normal(jax.random.fold_in(key, 3),
                                (b, s, cfg.d_model))
        batch_s["enc_embeds"] = batch_s1["enc_embeds"] = enc

    logits_s1, _ = api.prefill_fn(params, batch_s1, cfg, rt, masks)

    _, cache = api.prefill_fn(params, batch_s, cfg, rt, masks)
    # grow KV caches by one slot so decode can write at position s
    def grow(leaf):
        if leaf.ndim >= 2 and leaf.shape[-2:] != () and any(
                d == s for d in leaf.shape):
            ax = [i for i, d in enumerate(leaf.shape) if d == s]
            pad = [(0, 0)] * leaf.ndim
            pad[ax[0]] = (0, 1)
            return jnp.pad(leaf, pad)
        return leaf
    if cfg.family in ("dense", "moe", "vlm", "hybrid"):
        cache = jax.tree.map(grow, cache)
    elif cfg.family == "encdec":
        # grow only the decoder SELF cache; padding the cross cache would
        # add a phantom encoder key (cross-attention is non-causal)
        cache["kv"] = {**cache["kv"],
                       "self": jax.tree.map(grow, cache["kv"]["self"])}
    logits_dec, _ = api.decode_fn(params, toks[:, s:s + 1], cache, cfg, rt,
                                  masks)
    # MLA decode uses the ABSORBED contraction order (scores against the
    # latent) — mathematically identical, numerically ~2e-3 on f32 logits
    atol = 5e-3 if cfg.use_mla else 2e-3
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_s1),
                               rtol=2e-3, atol=atol)
