"""Scaled-down dry-run in a SUBPROCESS (own XLA_FLAGS: 16 host devices,
4x4 / 2x2x4 mesh) — proves the full lower+compile+roofline path end-to-end
without disturbing this process's single-device jax."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cell(arch, shape, multi_pod=False, fl_round=False, tmp="/tmp/dr"):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_HOST_DEVICES="16",
               REPRO_MESH="2x2x4" if multi_pod else "4x4")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", tmp]
    if multi_pod:
        cmd.append("--multi-pod")
    if fl_round:
        cmd.append("--fl-round")
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    tag = ("multi" if multi_pod else "single") + ("_fl" if fl_round else "")
    with open(os.path.join(tmp, f"{arch}_{shape}_{tag}.json")) as f:
        return json.load(f)


@pytest.mark.slow
def test_dryrun_train_single(tmp_path):
    rec = _run_cell("xlstm-125m", "train_4k", tmp=str(tmp_path))
    assert rec["status"] == "ok"
    r = rec["roofline"]
    assert r["t_compute_s"] > 0 and r["t_memory_s"] > 0
    assert rec["collective_bytes"] > 0          # DP grad sync must exist
    assert rec["memory"].get("peak_bytes", 1) < 16e9   # fits v5e HBM


@pytest.mark.slow
def test_dryrun_decode_multi_pod(tmp_path):
    rec = _run_cell("xlstm-125m", "decode_32k", multi_pod=True,
                    tmp=str(tmp_path))
    assert rec["status"] == "ok"
    assert rec["mesh"] == [2, 2, 4]


@pytest.mark.slow
def test_dryrun_fl_round_multi_pod(tmp_path):
    """The federated round step (pods = clients) lowers and compiles."""
    rec = _run_cell("xlstm-125m", "train_4k", multi_pod=True, fl_round=True,
                    tmp=str(tmp_path))
    assert rec["status"] == "ok"
    assert rec["fl_round"] is True


def test_long500k_skip_reason():
    from repro.configs import ARCHS, applicable, get_shape
    ok, why = applicable(ARCHS["deepseek-7b"], get_shape("long_500k"))
    assert not ok and "full-attention" in why
    ok2, _ = applicable(ARCHS["zamba2-1.2b"], get_shape("long_500k"))
    assert ok2
