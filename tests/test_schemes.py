"""The scheme seam: published baselines on every engine, and the
scheme-string drift fixes.

Pins (a) each new baseline (SCAFFOLD control variates, FLuID invariant
dropout, delayed-gradient hybrid) to ONE trajectory across the
sequential, async-fallback, batched and sharded engines, (b) the seam
itself — runtime.py contains NO inline scheme-string comparison, the
time_weighted sampler and the round clock bill stragglers through the
same Scheme.effective_volume hook so the two paths cannot disagree —
and (c) the uplink/clock semantics each baseline claims (SCAFFOLD's 2x
dense uplink, delayed's capable-only critical path), plus the compile
budgets under contracts.
"""
import os

if os.environ.get("REPRO_HOST_DEVICES") and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_HOST_DEVICES"])

import dataclasses
import re

import jax
import numpy as np
import pytest

from repro.analysis import contracts as CT
from repro.configs import CNNS, HeliosConfig, reduced
from repro.data.federated import partition_iid
from repro.data.synthetic import class_gaussian_images
from repro.federated import (SCHEMES, AsyncFLRun, BatchedFLRun, FLRun,
                             ShardedFLRun, make_adapter, make_fleet,
                             make_scheme, setup_clients)
from repro.federated.heterogeneity import cycle_time

NEW_SCHEMES = ("scaffold", "fluid", "delayed")
ENGINES = (FLRun, AsyncFLRun, BatchedFLRun, ShardedFLRun)


@pytest.fixture(scope="module")
def setting():
    cfg = reduced(CNNS["lenet"])
    imgs, labels = class_gaussian_images(400, cfg.image_size,
                                         cfg.in_channels, cfg.num_classes,
                                         seed=0)
    ti, tl = class_gaussian_images(64, cfg.image_size, cfg.in_channels,
                                   cfg.num_classes, seed=9)
    parts = partition_iid(len(labels), 8, seed=0)
    return cfg, {"images": imgs, "labels": labels}, \
        {"images": ti, "labels": tl}, parts


def _make(setting, cls, scheme, **kw):
    cfg, train, test, parts = setting
    hcfg = HeliosConfig()
    clients = setup_clients(make_fleet(4, 4), parts, hcfg)
    return cls(cfg, hcfg, scheme, clients, train, test,
               local_steps=1, batch_size=8, lr=0.1, seed=0, eval_batch=64,
               **kw)


def _diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                   - np.asarray(y, np.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# one trajectory per baseline across all four engines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", NEW_SCHEMES)
def test_baseline_four_engine_wall(setting, scheme):
    """scaffold / fluid / delayed reproduce one trajectory on the
    sequential, async-fallback, batched and sharded engines, with
    identical uplink accounting (the ISSUE's acceptance bar)."""
    runs = []
    for cls in ENGINES:
        r = _make(setting, cls, scheme)
        r.run_sync(3, eval_every=0)
        runs.append(r)
    seq = runs[0]
    for other in runs[1:]:
        assert _diff(seq.global_params, other.global_params) < 1e-5, \
            type(other).__name__
        assert other.uplink_updates == seq.uplink_updates
        assert abs(other.uplink_bytes() - seq.uplink_bytes()) < 1e-3


@pytest.mark.parametrize("scheme", NEW_SCHEMES)
def test_baseline_sampled_wall(setting, scheme):
    """Partial participation exercises the per-cohort control-row
    gather/scatter (scaffold) and stale-base rows (delayed): same
    schedule, same trajectory, seq <-> batched."""
    seq = _make(setting, FLRun, scheme, participation=4)
    seq.run_sync(3, eval_every=0)
    bat = _make(setting, BatchedFLRun, scheme, participation=4)
    bat.run_sync(3, eval_every=0)
    assert seq.cohort_log == bat.cohort_log
    assert _diff(seq.global_params, bat.global_params) < 1e-5


def test_baselines_compose_with_compression(setting):
    """A baseline scheme under the lossy uplink codec is still one
    trajectory seq <-> batched (scaffold's control delta stays raw; only
    the param delta rides the codec)."""
    for scheme in ("scaffold", "delayed"):
        seq = _make(setting, FLRun, scheme, compression="topk")
        seq.run_sync(2, eval_every=0)
        bat = _make(setting, BatchedFLRun, scheme, compression="topk")
        bat.run_sync(2, eval_every=0)
        assert _diff(seq.global_params, bat.global_params) < 1e-4, scheme
        assert abs(seq.uplink_bytes() - bat.uplink_bytes()) < 1e-3


# ---------------------------------------------------------------------------
# the semantics each baseline claims
# ---------------------------------------------------------------------------


def test_scaffold_uplink_is_double_dense(setting):
    """SCAFFOLD's control delta rides along dense: exactly 2x the uplink
    of the plain synchronous baseline over the same cohort schedule."""
    syn = _make(setting, BatchedFLRun, "syn")
    syn.run_sync(2, eval_every=0)
    sca = _make(setting, BatchedFLRun, "scaffold")
    sca.run_sync(2, eval_every=0)
    assert sca.uplink_updates == syn.uplink_updates
    assert sca.uplink_bytes() == pytest.approx(2.0 * syn.uplink_bytes())


def test_scaffold_controls_grow_with_participation(setting):
    """Client controls live in a lazily-materialized host store: zero
    rows are the correct init, and only sampled clients ever get one."""
    run = _make(setting, BatchedFLRun, "scaffold", participation=3)
    run.run_sync(3, eval_every=0)
    seen = {run.clients[i].cid for c in run.cohort_log for i in c}
    assert run._ctrl_store.touched() == len(seen) <= len(run.clients)
    # c_global moved off its zero init once deltas folded in
    assert max(float(np.max(np.abs(np.asarray(x))))
               for x in jax.tree.leaves(run._c_global)) > 0.0


def test_delayed_round_clock_is_capable_critical_path(setting):
    """Delayed-gradient stragglers never gate the clock: the simulated
    round duration is the capable cohort's critical path, strictly below
    the synchronized scheme's wait-for-all over the same fleet."""
    sch = make_scheme("delayed")
    syn = make_scheme("syn")
    clients = _make(setting, FLRun, "delayed").clients
    times = [cycle_time(c.profile, 1.0) for c in clients]
    d = sch.round_duration(times, clients)
    s = syn.round_duration(times, clients)
    capable = [t for t, c in zip(times, clients) if not c.is_straggler]
    assert d == max(capable) < s == max(times)


def test_delayed_stragglers_read_stale_base(setting):
    """After D rounds the delayed scheme's stale base is the global from
    D rounds back — not the fresh one."""
    run = _make(setting, FLRun, "delayed")
    run.run_sync(1, eval_every=0)
    after_r0 = jax.tree.map(np.asarray, run.global_params)
    # rounds 1-3; round 3's base is snapshot max(0, 3-2) = end of round 0
    run.run_sync(3, eval_every=0)
    assert _diff(run._stale_base, after_r0) == 0.0
    assert _diff(run._stale_base, run.global_params) > 0.0


# ---------------------------------------------------------------------------
# the drift fixes: one volume definition, no inline scheme strings
# ---------------------------------------------------------------------------


def test_runtime_has_no_inline_scheme_comparisons():
    """The seam is total: runtime.py never compares the scheme string.
    Every behavioral fork goes through the Scheme object (this is the
    regression test for the pre-seam sampler/clock drift bug, where the
    time_weighted weights and the round clock each hard-coded their own
    straggler-volume conditional and disagreed for full-volume
    schemes)."""
    import repro.federated.runtime as RT
    src = open(RT.__file__).read()
    assert not re.search(
        r"\bscheme\s*(==|!=|\bin\b|not\s+in)", src), \
        "inline scheme-string comparison reintroduced in runtime.py"


def test_sampler_and_clock_share_volume_definition(setting):
    """The two consumers of straggler volume — the time_weighted cohort
    sampler and the simulated round clock — cannot disagree: replaying
    the sampler from a cloned rng with weights built from
    Scheme.effective_volume (the clock's definition) reproduces the
    engine's drawn cohorts exactly, including across volume
    adaptation."""
    for scheme in ("helios", "scaffold"):      # adaptive and full-volume
        run = _make(setting, FLRun, scheme, participation=3,
                    sampler="time_weighted")
        rng = np.random.default_rng((run.seed, 0x5EED))
        sch = make_scheme(scheme)
        for _ in range(4):
            t = np.asarray([cycle_time(c.profile, sch.effective_volume(c))
                            for c in run.clients])
            w = 1.0 / np.maximum(t, 1e-9)
            exp = sorted(int(i) for i in rng.choice(
                len(run.clients), size=3, replace=False, p=w / w.sum()))
            run.run_sync(1, eval_every=0)
            assert run.cohort_log[-1] == exp, scheme


def test_full_volume_schemes_bill_stragglers_at_one(setting):
    """full_volume schemes (syn / scaffold / delayed) bill every client
    at volume 1.0 regardless of the straggler flag; soft-training
    schemes bill the straggler's adapted volume."""
    run = _make(setting, FLRun, "helios")
    strag = next(c for c in run.clients if c.is_straggler)
    for name in ("syn", "scaffold", "delayed"):
        assert make_scheme(name).effective_volume(strag) == 1.0
    assert make_scheme("helios").effective_volume(strag) == strag.volume


# ---------------------------------------------------------------------------
# registry + error-message seams
# ---------------------------------------------------------------------------


def test_scheme_registry_complete():
    assert set(SCHEMES) == {"helios", "syn", "st_only", "random",
                            "asyn", "afo", "scaffold", "fluid", "delayed"}
    for name, cls in SCHEMES.items():
        assert cls.name == name
        assert not (cls.async_native and cls.soft_training)


def test_make_scheme_unknown_lists_supported():
    with pytest.raises(ValueError, match="helios") as ei:
        make_scheme("fedavg2")
    assert "scaffold" in str(ei.value) and "fedavg2" in str(ei.value)


def test_make_adapter_unsupported_family_names_both_sides(setting):
    """The adapter dispatch error names the unsupported family AND the
    supported ones, so a config typo reads as a one-line diagnosis."""
    cfg = dataclasses.replace(setting[0], family="vlm")
    with pytest.raises(NotImplementedError, match="'vlm'") as ei:
        make_adapter(cfg)
    msg = str(ei.value)
    assert "cnn" in msg and "moe" in msg and "supported" in msg


# ---------------------------------------------------------------------------
# contracts: the new schemes keep the compile budgets
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", NEW_SCHEMES)
def test_new_schemes_pass_contracts(setting, scheme):
    """Batched + sharded under REPRO_CONTRACTS: control/stale-base rows
    move host<->device only through expected transfers, and each cache
    key still compiles exactly one program."""
    with CT.override(True):
        bat = _make(setting, BatchedFLRun, scheme, participation=4)
        bat.run_sync(3, eval_every=0)
        CT.check_compile_budget(bat, tag=f"test.{scheme}.batched")
        sh = _make(setting, ShardedFLRun, scheme)
        sh.run_sync(2, eval_every=0)
        CT.check_compile_budget(sh, tag=f"test.{scheme}.sharded")
