"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.masked_matmul import masked_matmul
from repro.kernels.ssd_scan import ssd_diag


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n,bn", [
    (128, 128, 256, 128),
    (256, 384, 512, 128),
    (128, 256, 384, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("frac", [1.0, 0.5, 0.25])
def test_masked_matmul(m, k, n, bn, dtype, frac):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k), dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), dtype)
    nb = n // bn
    alive = (jax.random.uniform(jax.random.fold_in(key, 2), (nb,)) < frac)
    alive = alive.at[0].set(True)                    # at least one live block
    got = masked_matmul(x, w, alive, block_m=128, block_n=bn, block_k=128,
                        interpret=True)
    want = ref.masked_matmul_ref(x, w, alive, bn)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_masked_matmul_skips_flops():
    """Dead blocks produce exact zeros (the skip actually happened)."""
    x = jnp.ones((128, 128))
    w = jnp.ones((128, 256))
    alive = jnp.array([1, 0], jnp.int32)
    y = masked_matmul(x, w, alive, interpret=True)
    assert float(jnp.abs(y[:, 128:]).max()) == 0.0
    assert float(jnp.abs(y[:, :128]).min()) > 0.0


@pytest.mark.parametrize("b,h,sq,sk,hd", [
    (1, 2, 256, 256, 64),
    (2, 1, 128, 384, 32),
    (1, 4, 384, 384, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(b, h, sq, sk, hd, dtype, causal):
    if causal and sq != sk:
        pytest.skip("causal requires square here")
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (b, h, sq, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, sk, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, sk, hd), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **_tol(dtype))


def test_flash_matches_model_chunked():
    """Kernel == the model's pure-JAX chunked attention (same schedule)."""
    from repro.models.layers import chunked_attention
    key = jax.random.PRNGKey(3)
    b, s, h, hd = 2, 512, 4, 64
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))
    got = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=True,
                          interpret=True).transpose(0, 2, 1, 3)
    want = chunked_attention(q, k, v, causal=True, q_chunk=128, kv_chunk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("b,nc,L,ds,nh,hd", [
    (1, 2, 64, 16, 2, 32),
    (2, 1, 128, 64, 4, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_diag(b, nc, L, ds, nh, hd, dtype):
    key = jax.random.PRNGKey(2)
    cr = jax.random.normal(key, (b, nc, L, ds), dtype)
    br = jax.random.normal(jax.random.fold_in(key, 1), (b, nc, L, ds), dtype)
    # decreasing cumulative log-decay (realistic: a <= 0)
    a = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 2),
                                   (b, nc, L, nh), jnp.float32)) * 0.1
    cum = jnp.cumsum(a, axis=2)
    dtx = jax.random.normal(jax.random.fold_in(key, 3), (b, nc, L, nh, hd),
                            dtype)
    got = ssd_diag(cr, br, cum, dtx, interpret=True)
    want = ref.ssd_diag_ref(cr, br, cum, dtx)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_ssd_kernel_matches_model_path():
    """ssd_diag == the intra-chunk term inside models/ssm.ssd_chunked when
    the inter-chunk state is zero (single chunk, h0=None)."""
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(5)
    b, s, nh, hd, ds = 1, 64, 2, 32, 16
    xh = jax.random.normal(key, (b, s, nh, hd))
    Bm = jax.random.normal(jax.random.fold_in(key, 1), (b, s, ds))
    Cm = jax.random.normal(jax.random.fold_in(key, 2), (b, s, ds))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3),
                                           (b, s, nh)))
    A = -jnp.ones((nh,))
    y, _ = ssd_chunked(xh, Bm, Cm, dt, A, chunk=s)     # one chunk: diag only
    a = (dt * A[None, None, :]).astype(jnp.float32)
    cum = jnp.cumsum(a.reshape(b, 1, s, nh), axis=2)
    dtx = (dt[..., None] * xh).reshape(b, 1, s, nh, hd)
    got = ssd_diag(Cm.reshape(b, 1, s, ds), Bm.reshape(b, 1, s, ds),
                   cum, dtx, interpret=True)
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(y),
                               rtol=1e-4, atol=1e-4)


def test_block_align_mask():
    m = jnp.array([1, 0, 0, 0, 0, 0, 0, 1], jnp.float32)
    out = ops.block_align_mask(m, 4)
    np.testing.assert_array_equal(np.asarray(out),
                                  [1, 1, 1, 1, 1, 1, 1, 1])
    m2 = jnp.array([0, 0, 0, 0, 1, 0, 0, 0], jnp.float32)
    np.testing.assert_array_equal(np.asarray(ops.block_align_mask(m2, 4)),
                                  [0, 0, 0, 0, 1, 1, 1, 1])
