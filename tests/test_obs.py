"""The telemetry layer (repro.obs): arming, determinism, accounting.

Pins the walls the observability PR claims:

* disarmed is FREE — a disarmed recorder buffers zero events and a run
  with telemetry off reproduces the armed run's trajectory bit-for-bit
  (accounting is engine bookkeeping either way; emission never touches
  the math);
* the legacy engine counters (``events_processed``, ``agg_counter``,
  ``uplink_*``, ``snapshot_*``) are thin views over the recorder — the
  ONE accounting surface;
* fixed-seed sim-time event streams are ENGINE-INVARIANT: the sync trio
  (sequential / batched / sharded) emits identical ``sim_events()``, and
  the async pair (sequential reference / bucketed) emits identical
  completion+drop streams;
* every history row names its recording cadence (``round`` / ``event`` /
  ``bucket``) and the bucketed cadence records a SUBSET of the
  sequential event cadence's cycles (one row per bucket, never per
  event — the documented divergence, now pinned instead of silent);
* downlink accounting is the dense-broadcast twin of uplink (equal for
  uncompressed schemes, half of SCAFFOLD's 2x uplink);
* telemetry composes with the contract walls: REPRO_OBS=on under
  REPRO_CONTRACTS=on adds no host transfers and no compiled programs;
* the ``repro.obs report``/``diff`` CLI renders a flushed run log and
  exits nonzero on an injected regression, and the
  benchmarks/check_regression.py gates fire on the invariants they
  state.
"""
import os

if os.environ.get("REPRO_HOST_DEVICES") and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_HOST_DEVICES"])

import importlib.util
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts as CT
from repro.configs import CNNS, HeliosConfig, reduced
from repro.data.federated import partition_iid
from repro.data.synthetic import class_gaussian_images
from repro.federated import (AsyncFLRun, BatchedFLRun, FLRun, ShardedFLRun,
                             make_fleet, setup_clients)
from repro.obs import recorder as OBS
from repro.obs import report as OBR

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ROUNDS = 2


@pytest.fixture(scope="module")
def setting():
    cfg = reduced(CNNS["lenet"])
    imgs, labels = class_gaussian_images(400, cfg.image_size,
                                         cfg.in_channels, cfg.num_classes,
                                         seed=0)
    ti, tl = class_gaussian_images(64, cfg.image_size, cfg.in_channels,
                                   cfg.num_classes, seed=9)
    parts = partition_iid(len(labels), 8, seed=0)
    return cfg, {"images": imgs, "labels": labels}, \
        {"images": ti, "labels": tl}, parts


def _make(setting, cls, scheme="helios", **kw):
    cfg, train, test, parts = setting
    hcfg = HeliosConfig()
    clients = setup_clients(make_fleet(4, 4), parts, hcfg)
    return cls(cfg, hcfg, scheme, clients, train, test,
               local_steps=1, batch_size=8, lr=0.1, seed=0, eval_batch=64,
               **kw)


def _diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                   - np.asarray(y, np.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# recorder unit behavior
# ---------------------------------------------------------------------------


def test_disarmed_recorder_counts_but_never_emits():
    rec = OBS.Recorder(armed=False)
    rec.inc("a")
    rec.inc("a", 2)
    rec.set_max("m", 5)
    rec.set_max("m", 3)
    rec.gauge("g", 1.5)
    rec.event("round", sim=0.0, x=1)
    rec.observe("h", 1.0)
    with rec.span("s", sim=0.0):
        pass
    assert rec.counters == {"a": 3, "m": 5}
    assert rec.gauges == {"g": 1.5}
    assert rec.events == [] and rec.hists == {}
    rec.accum("c", jnp.float32(2.0))
    rec.accum("c", jnp.float32(3.0))
    assert rec.accum_value("c") == 5.0
    assert rec.accum_value("missing", 7.0) == 7.0


def test_armed_recorder_flush_roundtrip(tmp_path):
    rec = OBS.Recorder(armed=True, manifest={"engine": "unit"})
    rec.event("round", sim=1.0, round=0)
    rec.observe("staleness", 2.0)
    with rec.span("train", sim=1.0, round=0):
        pass
    out = rec.flush(str(tmp_path / "run"))
    lines = [json.loads(line)
             for line in open(out["events"]) if line.strip()]
    assert lines[0]["kind"] == "manifest" and lines[0]["engine"] == "unit"
    assert lines[-1]["kind"] == "summary" and lines[-1]["events"] == 2
    assert json.load(open(out["manifest"]))["engine"] == "unit"
    # sim view strips the wall clock but keeps every sim-side field
    sims = rec.sim_events()
    assert [e["kind"] for e in sims] == ["round", "span"]
    assert all("wall" not in e and "wall_ms" not in e for e in sims)
    assert out["summary"]["hists"]["staleness"]["count"] == 1


# ---------------------------------------------------------------------------
# disarmed is free; accounting views are back-compatible
# ---------------------------------------------------------------------------


def test_disarmed_run_zero_events_bit_identical_trajectory(setting):
    with OBS.override(False):
        off = _make(setting, BatchedFLRun)
        h_off = off.run_sync(ROUNDS)
    with OBS.override(True):
        on = _make(setting, BatchedFLRun)
        h_on = on.run_sync(ROUNDS)
    assert not off.rec.armed and off.rec.events == []
    assert on.rec.armed and on.rec.events
    assert _diff(off.global_params, on.global_params) == 0.0
    assert [h["acc"] for h in h_off] == [h["acc"] for h in h_on]
    # accounting is identical either way — it IS the engine bookkeeping
    assert off.rec.counters == {k: v for k, v in on.rec.counters.items()
                                if not k.startswith("contracts.")}


def test_legacy_counter_views_are_recorder_views(setting):
    with OBS.override(False):
        run = _make(setting, FLRun)
        run.run_sync(ROUNDS)
    n = ROUNDS * len(run.clients)
    assert run.uplink_updates == n == run.rec.count("uplink_updates")
    assert run.downlink_updates == n
    assert run.uplink_extra_updates == 0
    assert run.uplink_bytes() == run.downlink_bytes() > 0
    with OBS.override(False):
        arun = _make(setting, AsyncFLRun, "afo")
        arun.run_async(6)
    assert arun.events_processed == arun.rec.count("events_processed") > 0
    assert arun.agg_counter == arun.events_processed
    assert arun.snapshot_peak == arun.rec.count("snapshot_peak", 1) >= 1
    assert arun.snapshot_anchor_misses == 0
    assert arun.downlink_updates == arun.events_processed


def test_scaffold_uplink_is_twice_downlink(setting):
    with OBS.override(False):
        run = _make(setting, FLRun, "scaffold")
        run.run_sync(ROUNDS)
    assert run.uplink_extra_updates == run.uplink_updates
    assert run.uplink_bytes() == 2 * run.downlink_bytes()


# ---------------------------------------------------------------------------
# fixed-seed sim streams are engine-invariant
# ---------------------------------------------------------------------------


def test_sync_trio_identical_sim_event_streams(setting):
    streams = []
    for cls in (FLRun, BatchedFLRun, ShardedFLRun):
        with OBS.override(True):
            run = _make(setting, cls)
            run.run_sync(ROUNDS)
        streams.append(run.rec.sim_events())
    assert streams[0] == streams[1] == streams[2]
    kinds = {e["kind"] for e in streams[0]}
    assert {"round", "span", "volumes"} <= kinds


def test_async_pair_identical_completion_streams(setting):
    runs = []
    for cls in (FLRun, AsyncFLRun):
        with OBS.override(True):
            run = _make(setting, cls, "afo")
            run.run_async(6)
        runs.append(run)
    seq, buck = runs
    kinds = ("completion", "drop")
    assert seq.rec.sim_events(kinds) == buck.rec.sim_events(kinds)
    assert seq.rec.sim_events(("completion",))
    assert seq.agg_counter == buck.agg_counter
    assert seq.events_processed == buck.events_processed
    # the event core's own census: same arrival stream, same high water
    assert seq.rec.count("queue_peak") \
        == buck.rec.count("queue_peak") > 0


# ---------------------------------------------------------------------------
# record_cadence: every history row names how it was recorded
# ---------------------------------------------------------------------------


def test_record_cadence_pins_the_async_divergence(setting):
    with OBS.override(False):
        sync = _make(setting, FLRun)
        h_sync = sync.run_sync(ROUNDS)
        seq = _make(setting, FLRun, "afo")
        h_seq = seq.run_async(6)
        buck = _make(setting, AsyncFLRun, "afo")
        h_buck = buck.run_async(6)
    assert [h["record_cadence"] for h in h_sync] == ["round"] * len(h_sync)
    assert {h["record_cadence"] for h in h_seq} == {"event"}
    assert {h["record_cadence"] for h in h_buck} == {"bucket"}
    # the documented relationship at eval_every=1: the sequential
    # reference records at EVERY capable completion (cycles 1..N), the
    # bucketed engine once per bucket — its cycles are a subset of the
    # sequential ones and both end at the same completion count
    seq_cycles = [h["cycle"] for h in h_seq]
    buck_cycles = [h["cycle"] for h in h_buck]
    assert seq_cycles == list(range(1, len(seq_cycles) + 1))
    assert set(buck_cycles) <= set(seq_cycles)
    assert buck_cycles == sorted(buck_cycles)
    assert buck_cycles[-1] == seq_cycles[-1]
    # downlink grows monotonically in every cadence's rows
    for hist in (h_sync, h_seq, h_buck):
        mb = [h["downlink_mb"] for h in hist]
        assert mb == sorted(mb) and mb[-1] > 0


# ---------------------------------------------------------------------------
# telemetry under the contract walls
# ---------------------------------------------------------------------------


def test_obs_on_composes_with_contracts(setting):
    """REPRO_OBS=on under REPRO_CONTRACTS=on: the transfer guard and the
    compile budget run inside run_sync and must hold unchanged; the run
    log gains the contracts bridge (compile census + contract counters)
    and the compression error-store census."""
    CT.reset_counters()
    with OBS.override(True), CT.override(True):
        run = _make(setting, BatchedFLRun, compression="topk")
        run.run_sync(ROUNDS)
    assert run.rec.count("contracts.guarded_sections") \
        == CT.counters["guarded_sections"] > 0
    compile_evs = [e for e in run.rec.events if e["kind"] == "compile"]
    assert {e["seam"] for e in compile_evs} >= {"local_train"}
    store = [e for e in run.rec.events if e["kind"] == "error_store"]
    assert store and store[-1]["rows"] == len(run.clients)


# ---------------------------------------------------------------------------
# CLI: report renders, diff gates
# ---------------------------------------------------------------------------


def _cli(args, cwd=ROOT):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    return subprocess.run([sys.executable, "-m"] + args, cwd=cwd, env=env,
                          capture_output=True, text=True)


@pytest.mark.slow
def test_report_and_diff_cli(tmp_path, setting):
    with OBS.override(True):
        run = _make(setting, BatchedFLRun)
        run.run_sync(ROUNDS)
    out = run.rec.flush(str(tmp_path / "run"))
    r = _cli(["repro.obs", "report", str(tmp_path / "run")])
    assert r.returncode == 0, r.stderr
    for section in ("run manifest", "per-round table", "span census"):
        assert section in r.stdout
    # identical runs: no regression
    r = _cli(["repro.obs", "diff", out["events"], out["events"]])
    assert r.returncode == 0 and "no regressions" in r.stdout
    # injected regression fixture: halve the recorded accuracy
    bad = tmp_path / "bad.jsonl"
    with open(out["events"]) as f, open(bad, "w") as g:
        for line in f:
            ev = json.loads(line)
            if ev.get("kind") == "history" and "acc" in ev:
                ev["acc"] *= 0.5
            g.write(json.dumps(ev) + "\n")
    r = _cli(["repro.obs", "diff", out["events"], str(bad)])
    assert r.returncode == 1 and "REGRESSION" in r.stdout


def test_summarize_and_diff_units(setting):
    with OBS.override(True):
        run = _make(setting, BatchedFLRun)
        hist = run.run_sync(ROUNDS)
        run._obs_finish("unit")
    summ = OBR.summarize(run.rec.events
                         + [{"kind": "summary", **run.rec.snapshot()}])
    assert summ["rounds"] == len(hist)
    assert summ["metric_name"] == "acc"
    assert summ["final_metric"] == hist[-1]["acc"]
    assert summ["uplink_mb"] == pytest.approx(run.uplink_bytes() / 1e6)
    assert summ["downlink_mb"] == pytest.approx(run.downlink_bytes() / 1e6)
    # loss-like metrics invert the better-direction: a LOWER ce is ok
    old = [{"kind": "history", "sim": 1.0, "cycle": 1, "ce": 2.0}]
    new = [{"kind": "history", "sim": 1.0, "cycle": 1, "ce": 1.0}]
    _, regressions = OBR.diff(old, new)
    assert not regressions
    _, regressions = OBR.diff(new, old)
    assert regressions == ["final_metric"]


# ---------------------------------------------------------------------------
# the CI regression gate fires on what it states
# ---------------------------------------------------------------------------


def _load_check_regression():
    spec = importlib.util.spec_from_file_location(
        "check_regression",
        os.path.join(ROOT, "benchmarks", "check_regression.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_regression_units():
    mod = _load_check_regression()
    obs = {"rounds": 3, "overhead_frac": 0.02,
           "results": {"off": {"events": 0}},
           "summary": {"counters": {"uplink_updates": 12,
                                    "downlink_updates": 12},
                       "sim_time": 3.0, "uplink_mb": 1.0,
                       "downlink_mb": 1.0, "metric_name": "acc",
                       "final_metric": 0.5}}
    problems = []
    mod.check_observability(obs, obs, problems, 1.0, 0.5)
    assert problems == []
    bad = json.loads(json.dumps(obs))
    bad["results"]["off"]["events"] = 7
    bad["summary"]["counters"]["downlink_updates"] = 0
    bad["overhead_frac"] = 0.9
    problems = []
    mod.check_observability(bad, obs, problems, 1.0, 0.5)
    assert len(problems) == 3

    gau = {"schemes": {
        "syn": {"engine": "BatchedFLRun", "uplink_mb": 1.0,
                "downlink_mb": 1.0},
        "scaffold": {"engine": "BatchedFLRun", "uplink_mb": 2.0,
                     "downlink_mb": 1.0}}}
    problems = []
    mod.check_gauntlet(gau, gau, problems)
    assert problems == []
    bad = json.loads(json.dumps(gau))
    bad["schemes"]["scaffold"]["uplink_mb"] = 1.0     # 2x cost vanished
    bad["schemes"]["syn"]["downlink_mb"] = 0.0
    problems = []
    mod.check_gauntlet(bad, gau, problems)
    assert len(problems) == 2

    con = {"results": {"off": {"counters": {"blocked_transfers": 0}},
                       "on": {"counters": {"finite_checks": 4}}}}
    problems = []
    mod.check_contracts(con, con, problems)
    assert problems == []
    bad = json.loads(json.dumps(con))
    bad["results"]["on"]["counters"]["finite_checks"] = 0
    problems = []
    mod.check_contracts(bad, con, problems)
    assert problems == ["on-mode check family finite_checks collapsed to "
                        "zero (committed ran 4)"]
