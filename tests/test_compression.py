"""Properties of the uplink codec (optim/compression.py): top-k keep
bounds, error-feedback telescoping, quantization round-trip error, exact
zeros on Eq. 2-masked coordinates, and the byte accounting the bench and
``FLRun.uplink_bytes`` report.

Hypothesis properties run when hypothesis is installed (same guard as
test_theory_property.py); the deterministic cases always run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import compression as CP

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAVE_HYP = True
except ImportError:                                     # pragma: no cover
    HAVE_HYP = False

needs_hyp = pytest.mark.skipif(not HAVE_HYP, reason="hypothesis missing")


def _tree(seed=0, shapes=((8, 16), (16,), (4, 4, 3))):
    rng = np.random.default_rng(seed)
    return {f"w{i}": jnp.asarray(rng.normal(size=s).astype(np.float32))
            for i, s in enumerate(shapes)}


def _total(tree):
    return sum(l.size for l in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# deterministic unit cases
# ---------------------------------------------------------------------------


def test_init_error_respects_param_dtype():
    params = {"a": jnp.zeros((3, 2), jnp.float16),
              "b": jnp.zeros((4,), jnp.float32)}
    err = CP.init_error(params)
    assert err["a"].dtype == jnp.float16
    assert err["b"].dtype == jnp.float32
    assert all(float(jnp.sum(jnp.abs(l))) == 0.0
               for l in jax.tree.leaves(err))


def test_compressed_bytes_per_leaf_accounting():
    """Bytes must sum per-leaf k = max(1, round(frac*size)) — a tree of
    many tiny leaves keeps one coord per leaf, which a single global
    round() under-reports."""
    g = {f"t{i}": jnp.ones((3,)) for i in range(10)}      # 30 params
    # frac=0.01: global round(0.3) == 0, per-leaf max(1, round(0.03)) == 1
    assert CP.compressed_bytes(g, 0.01) == 10 * (4 + 4)
    big = {"w": jnp.ones((1000,))}
    assert CP.compressed_bytes(big, 0.05) == 50 * (4 + 4)


def test_topk_keeps_largest_magnitudes():
    x = jnp.asarray(np.arange(1.0, 101.0, dtype=np.float32))
    kept = CP._leaf_topk(x, 0.05)
    nz = np.flatnonzero(np.asarray(kept))
    assert len(nz) == 5
    assert set(nz.tolist()) == set(range(95, 100))


def test_quant_exact_zero_and_sign():
    x = jnp.asarray([0.0, -1.0, 1.0, 0.5, 0.0], jnp.float32)
    q, s = CP.quantize(x, bits=8)
    dec = np.asarray(CP.dequantize(q, s))
    assert dec[0] == 0.0 and dec[4] == 0.0                # exact zeros
    assert dec[1] < 0 < dec[2]
    np.testing.assert_allclose(dec, np.asarray(x), atol=float(s) / 2)


def test_masked_coords_never_sent_residual_preserved():
    """Eq. 2-masked coordinates encode as exact zeros in every mode, and
    their corrected value survives IN FULL in the residual (the rotation
    can wake them later)."""
    delta = _tree(1)
    err = CP.init_error(delta)
    masks = jax.tree.map(lambda x: (jnp.arange(x.size).reshape(x.shape)
                                    % 2).astype(jnp.float32), delta)
    for mode in ("topk", "quant", "delta"):
        sent, new_err, _ = CP.compress_update(delta, err, mode, frac=0.5,
                                              bits=8, masks=masks)
        for s, m, d, e in zip(jax.tree.leaves(sent), jax.tree.leaves(masks),
                              jax.tree.leaves(delta),
                              jax.tree.leaves(new_err)):
            s, m, d, e = map(np.asarray, (s, m, d, e))
            assert np.all(s[m == 0] == 0.0), mode
            np.testing.assert_allclose(e[m == 0], d[m == 0], rtol=1e-6,
                                       err_msg=mode)


def test_uplink_bytes_formulas():
    assert CP.uplink_bytes("none", 0, 100, 3) == 400.0
    assert CP.uplink_bytes("topk", 10, 100, 3) == 10 * 6.0
    assert CP.uplink_bytes("quant", 100, 100, 3, bits=8) == 100 + 12.0
    assert CP.uplink_bytes("delta", 10, 100, 3, bits=8) == 10 * 5 + 12.0
    with pytest.raises(ValueError):
        CP.uplink_bytes("bogus", 0, 1, 1)


def test_host_error_store_lazy_and_roundtrip():
    params = _tree(2)
    store = CP.HostErrorStore(params)
    assert store.touched() == 0 and store.nbytes() == 0
    # untouched reads are zeros and do NOT materialize rows
    z = store.gather([3, 7])
    assert all(float(np.abs(l).sum()) == 0.0 for l in jax.tree.leaves(z))
    assert store.touched() == 0
    upd = jax.tree.map(lambda x: x + 1.0, z)
    store.scatter([3, 7], upd)
    assert store.touched() == 2 and store.nbytes() > 0
    back = store.gather([7, 3, 5])
    rows = np.asarray(jax.tree.leaves(back)[0])
    assert np.all(rows[0] == 1.0) and np.all(rows[1] == 1.0)
    assert np.all(rows[2] == 0.0)                         # still lazy
    one = store.row(3)
    assert float(np.asarray(jax.tree.leaves(one)[0]).mean()) == 1.0


def test_compress_update_rejects_none():
    t = _tree(3)
    with pytest.raises(ValueError):
        CP.compress_update(t, CP.init_error(t), "none")


# ---------------------------------------------------------------------------
# hypothesis properties (guarded like tests/test_async_engine.py)
# ---------------------------------------------------------------------------

if HAVE_HYP:

    finite = hst.floats(-10.0, 10.0, allow_nan=False, allow_infinity=False,
                        width=32)

    @needs_hyp
    @settings(max_examples=25, deadline=None)
    @given(hst.lists(finite, min_size=4, max_size=64),
           hst.floats(0.01, 0.5))
    def test_topk_sent_fraction_bound(vals, frac):
        """The number of sent coordinates never exceeds the per-leaf
        k = max(1, round(frac*size)) budget."""
        x = jnp.asarray(np.asarray(vals, np.float32))
        kept = np.asarray(CP._leaf_topk(x, frac))
        assert int((kept != 0).sum()) <= CP.leaf_k(x.size, frac)

    @needs_hyp
    @settings(max_examples=15, deadline=None)
    @given(hst.integers(0, 2 ** 31 - 1), hst.floats(0.05, 0.5),
           hst.sampled_from(["topk", "quant", "delta"]))
    def test_error_feedback_telescoping(seed, frac, mode):
        """sum over cycles of sent + final residual == sum of raw deltas,
        exactly (by construction: new_err = corrected - sent) —
        compression never loses mass, it only defers it."""
        rng = np.random.default_rng(seed)
        shapes = ((6, 5), (7,))
        deltas = [{f"w{i}": jnp.asarray(
            rng.normal(size=s).astype(np.float32))
            for i, s in enumerate(shapes)} for _ in range(4)]
        err = CP.init_error(deltas[0])
        acc = jax.tree.map(jnp.zeros_like, deltas[0])
        for d in deltas:
            sent, err, _ = CP.compress_update(d, err, mode, frac=frac,
                                              bits=8)
            acc = jax.tree.map(lambda a, s: a + s, acc, sent)
        total = jax.tree.map(
            lambda *xs: sum(x.astype(jnp.float32) for x in xs), *deltas)
        recon = jax.tree.map(lambda a, e: a + e.astype(jnp.float32),
                             acc, err)
        for t, r in zip(jax.tree.leaves(total), jax.tree.leaves(recon)):
            np.testing.assert_allclose(np.asarray(t), np.asarray(r),
                                       atol=1e-4)

    @needs_hyp
    @settings(max_examples=25, deadline=None)
    @given(hst.lists(finite, min_size=1, max_size=64),
           hst.sampled_from([4, 6, 8]))
    def test_quant_roundtrip_error_bound(vals, bits):
        """|x - dequant(quant(x))| <= scale/2 everywhere (symmetric codes,
        no clipping: scale is set from max|x|)."""
        x = jnp.asarray(np.asarray(vals, np.float32))
        q, s = CP.quantize(x, bits)
        dec = np.asarray(CP.dequantize(q, s))
        assert np.max(np.abs(dec - np.asarray(x))) <= float(s) / 2 + 1e-7

    @needs_hyp
    @settings(max_examples=25, deadline=None)
    @given(hst.integers(0, 2 ** 31 - 1))
    def test_lossy_ring_roundtrip_consistency(seed):
        """aggregation.lossy_roundtrip (the sequential reference's
        stale-anchor decode) is idempotent: decoding a decoded tree
        changes nothing — the write-time and read-time codecs are the
        same math."""
        from repro.core import aggregation as AG
        rng = np.random.default_rng(seed)
        params = {"w": jnp.asarray(
            rng.normal(size=(5, 4)).astype(np.float32))}
        ref = jax.tree.map(lambda x: x * 0.5, params)
        for r in (None, ref):
            once = AG.lossy_roundtrip(params, r, 8)
            twice = AG.lossy_roundtrip(once, r, 8)
            for a, b in zip(jax.tree.leaves(once), jax.tree.leaves(twice)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-6)
