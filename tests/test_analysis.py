"""repro.analysis: the JAX-hazard linter (R1-R6) + runtime contracts.

Layer 1 (lint): every rule fires on a minimal violating fixture and is
silenced by ``# repro: noqa[Rn]`` on the finding line; the repo's own
``src/`` is clean (zero unsuppressed findings) and the once-orphaned
modules (optim/compression.py, core/theory.py, launch/serve.py) are all
WIRED — reached from production entry points, no R6 finding at all.

Layer 2 (contracts): the transfer guard blocks implicit device->host syncs
in engine hot loops (and a deliberately leaky engine subclass trips it),
checkify tripwires catch NaN aggregations, the domain checkers accept
valid Eq. 2 masks / staleness schedules / snapshot rings and reject
corrupted ones, and a contracts-ON batched engine run over >=3 distinct
sampled cohorts passes the one-program-per-signature compile budget.
Everything is a no-op with contracts off (counters stay zero).
"""
import os
import subprocess
import sys
import textwrap
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts as CT
from repro.analysis.lint import lint_paths, make_report, unsuppressed
from repro.configs import CNNS, HeliosConfig, reduced
from repro.core import aggregation as AG
from repro.core import selection as SEL
from repro.core import soft_train as ST
from repro.data.federated import partition_noniid
from repro.data.synthetic import class_gaussian_images
from repro.federated import (BatchedFLRun, FLRun, make_fleet,
                             setup_clients)
from repro.kernels import ops

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:                                    # container has no
    HAVE_HYP = False                                   # hypothesis: skip

needs_hyp = pytest.mark.skipif(not HAVE_HYP, reason="hypothesis not installed")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


# ---------------------------------------------------------------------------
# layer 1: lint fixtures per rule
# ---------------------------------------------------------------------------


def _lint(tmp_path, source, name="fixture.py", rules=None):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return lint_paths([str(p)], rules=rules)


def _rules(findings):
    return sorted({f.rule for f in findings if not f.suppressed})


R1_SRC = """
    import jax

    @jax.jit
    def f(x):
        if x > 0:{noqa}
            return x
        return -x
"""

R2_SRC = """
    import jax

    def f(key):
        a = jax.random.normal(key, (3,))
        b = jax.random.uniform(key, (3,)){noqa}
        return a + b
"""

R3_SRC = """
    import jax.numpy as jnp

    def f(xs):
        total = 0.0
        for x in xs:
            y = jnp.sin(x)
            total += float(y){noqa}
        return total
"""

R4_SRC = """
    import jax

    def f(xs):
        out = []
        for x in xs:
            out.append(jax.jit(lambda v: v * 2)(x)){noqa}
        return out
"""

R5_SRC = """
    import jax
    import jax.numpy as jnp

    step = jax.jit(lambda g: g + 1, donate_argnums=(0,))

    def f():
        g = jnp.zeros((4,))
        out = step(g)
        return out + g{noqa}
"""

R6_IMPORT_SRC = """
    import os{noqa}

    X = 1
"""


@pytest.mark.parametrize("rule,src,line_key", [
    ("R1", R1_SRC, "if x > 0:"),
    ("R2", R2_SRC, "uniform"),
    ("R3", R3_SRC, "float(y)"),
    ("R4", R4_SRC, "jax.jit(lambda"),
    ("R5", R5_SRC, "out + g"),
    ("R6", R6_IMPORT_SRC, "import os"),
])
def test_rule_fires_and_noqa_suppresses(tmp_path, rule, src, line_key):
    """Each rule flags its violating fixture at the expected line, and the
    same fixture with ``# repro: noqa[Rn]`` on that line reports zero
    unsuppressed findings (the finding stays in the full list)."""
    hot = _lint(tmp_path, src.format(noqa=""), name="hot.py")
    hits = [f for f in hot if f.rule == rule]
    assert hits, f"{rule} did not fire: {[str(f) for f in hot]}"
    assert all(not f.suppressed for f in hits)
    src_lines = textwrap.dedent(src.format(noqa="")).splitlines()
    assert any(line_key in src_lines[f.line - 1] for f in hits), \
        [str(f) for f in hits]

    cold = _lint(tmp_path, src.format(noqa=f"  # repro: noqa[{rule}]"),
                 name="cold.py")
    assert not [f for f in cold if f.rule == rule and not f.suppressed], \
        [str(f) for f in cold]
    assert [f for f in cold if f.rule == rule and f.suppressed]


def test_r1_ignores_static_and_closure_branches(tmp_path):
    """Shape tests and default-valued (closure-capture) params are not
    traced-value branches."""
    findings = _lint(tmp_path, """
        import jax

        kind = "moe"

        @jax.jit
        def f(x, kind=kind):
            if x.ndim == 2:
                x = x[None]
            if kind == "moe":
                return x * 2
            return x
    """)
    assert "R1" not in _rules(findings), [str(f) for f in findings]


def test_r2_rederived_keys_pass(tmp_path):
    """split/fold_in between consumptions is the sanctioned pattern."""
    findings = _lint(tmp_path, """
        import jax

        def f(key, n):
            out = []
            for i in range(n):
                sub = jax.random.fold_in(key, i)
                out.append(jax.random.normal(sub, (3,)))
            return out
    """)
    assert "R2" not in _rules(findings), [str(f) for f in findings]


def test_r2_loop_reuse_fires(tmp_path):
    """A key consumed inside a loop without re-derivation draws the same
    sample every iteration."""
    findings = _lint(tmp_path, """
        import jax

        def f(key, n):
            out = []
            for _ in range(n):
                out.append(jax.random.normal(key, (3,)))
            return out
    """)
    assert "R2" in _rules(findings)


def test_r5_reassign_pattern_passes(tmp_path):
    """The engines' donate-and-reassign idiom (``g = step(g)``) is safe."""
    findings = _lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        step = jax.jit(lambda g: g + 1, donate_argnums=(0,))

        def f():
            g = jnp.zeros((4,))
            for _ in range(3):
                g = step(g)
            return g
    """)
    assert "R5" not in _rules(findings), [str(f) for f in findings]


def _write_project(tmp_path, orphan_noqa=""):
    """Minimal src/repro tree with one live and one orphan module."""
    src = tmp_path / "src" / "repro"
    src.mkdir(parents=True)
    (src / "__init__.py").write_text("")
    (src / "live.py").write_text("def go():\n    return 1\n")
    (src / "orphan.py").write_text(
        f'"""Nobody imports me.{orphan_noqa}"""\n\nY = 2\n')
    ex = tmp_path / "examples"
    ex.mkdir()
    (ex / "run.py").write_text("from repro import live\n\nlive.go()\n")
    return src


def test_r6_orphan_module_fires(tmp_path):
    """A src/repro module unreachable from examples/benchmarks/-m entry
    points is an orphan; modules imported by an example are alive."""
    src = _write_project(tmp_path)
    findings = lint_paths([str(src)])
    orphans = [f for f in findings if f.rule == "R6" and "orphan" in f.message]
    assert [f for f in orphans if f.path.endswith("orphan.py")]
    assert not [f for f in orphans if f.path.endswith("live.py")]


def test_r6_orphan_noqa_in_docstring(tmp_path):
    """Module-level findings accept the noqa anywhere in the first 10
    lines — including inside the module docstring."""
    src = _write_project(tmp_path, orphan_noqa="  # repro: noqa[R6]")
    findings = lint_paths([str(src)])
    orphans = [f for f in findings
               if f.rule == "R6" and f.path.endswith("orphan.py")]
    assert orphans and all(f.suppressed for f in orphans)


def test_repo_src_is_lint_clean():
    """The gate CI enforces: zero unsuppressed findings over src/, and
    every once-orphaned module is WIRED now — optim/compression.py (the
    engines' compression knob), core/theory.py (the scheme-gauntlet
    bench's Prop. 2 report), and launch/serve.py (the serve-while-you-
    train traffic bench).  R6 must see each reached from a production
    entry point: no finding at all, suppressed or otherwise."""
    findings = lint_paths([SRC])
    assert unsuppressed(findings) == [], \
        [str(f) for f in unsuppressed(findings)]
    report = make_report(findings, [SRC])
    assert report["unsuppressed"] == 0
    r6_paths = [f["path"] for f in report["findings"] if f["rule"] == "R6"]
    for wired in (os.path.join("optim", "compression.py"),
                  os.path.join("core", "theory.py"),
                  os.path.join("launch", "serve.py")):
        assert not any(p.endswith(wired) for p in r6_paths), (wired,
                                                              r6_paths)


def test_cli_exit_codes(tmp_path):
    """``python -m repro.analysis lint`` exits 0 on clean input, 1 on an
    unsuppressed finding, and ``report`` writes the JSON artifact."""
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(R3_SRC.format(noqa="")))
    ok = tmp_path / "ok.py"
    ok.write_text("X = 1\n")
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-m", "repro.analysis", "lint",
                        str(ok)], env=env, capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    out_json = tmp_path / "report.json"
    r = subprocess.run([sys.executable, "-m", "repro.analysis", "lint",
                        str(bad), "--out", str(out_json)],
                       env=env, capture_output=True, text=True)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "R3" in r.stdout
    assert out_json.exists()


# ---------------------------------------------------------------------------
# layer 2: transfer guard + tripwires (no engines)
# ---------------------------------------------------------------------------


def test_transfer_guard_blocks_and_whitelists():
    x = jnp.ones(())
    with CT.override(True):
        with CT.no_host_transfers("test"):
            with pytest.raises(CT.ContractError, match="float"):
                float(x)
            with pytest.raises(CT.ContractError, match="numpy.asarray"):
                np.asarray(x)
            with pytest.raises(CT.ContractError, match="__bool__"):
                bool(x > 0)
            with CT.expected_transfer("metrics"):
                assert float(x) == 1.0          # whitelisted sync
        assert float(x) == 1.0                  # outside the section
    with CT.override(False):
        with CT.no_host_transfers("off"):
            assert float(x) == 1.0              # contracts off: no-op


def test_transfer_guard_jit_safe():
    """Compiling and running jitted programs inside a guarded section is
    fine — only explicit host conversions trip the guard."""
    @jax.jit
    def f(a):
        return jnp.sin(a).sum()

    with CT.override(True):
        with CT.no_host_transfers("jit"):
            y = f(jnp.arange(8.0))              # fresh compile in-section
            z = jax.tree.map(lambda t: t * 2, {"a": y})
        assert np.isfinite(float(z["a"]))


def test_assert_finite():
    with CT.override(True):
        CT.assert_finite({"w": jnp.ones((3,)), "b": jnp.zeros(())})
        with pytest.raises(CT.ContractError, match="nan_tree"):
            CT.assert_finite({"w": jnp.array([1.0, jnp.nan])},
                             tag="nan_tree")
        with pytest.raises(CT.ContractError):
            CT.assert_finite([jnp.array([jnp.inf])], tag="inf_tree")
        # integer leaves are exempt (finiteness is a float property)
        CT.assert_finite({"n": jnp.arange(3)})
    with CT.override(False):
        CT.assert_finite({"w": jnp.array([jnp.nan])})   # off: no-op


def test_aggregation_contract_catches_poisoned_mix():
    """The @contract post on aggregation.mix trips on a NaN client."""
    g = {"w": jnp.ones((4,))}
    bad = {"w": jnp.array([1.0, jnp.nan, 1.0, 1.0])}
    with CT.override(True):
        CT.reset_counters()
        AG.mix(g, {"w": jnp.zeros((4,))}, 0.5)      # healthy client: fine
        assert CT.counters["finite_checks"] >= 1
        with pytest.raises(CT.ContractError, match="aggregation"):
            AG.mix(g, bad, 0.5)
    with CT.override(False):
        out = AG.mix(g, bad, 0.5)                   # off: flows through
    assert not bool(jnp.all(jnp.isfinite(out["w"])))


def test_selection_and_kernel_preconditions():
    key = jax.random.PRNGKey(0)
    with CT.override(True):
        with pytest.raises(CT.ContractError, match="must be \\(L, n\\)"):
            SEL.select_masks({"fc": jnp.ones((16,))}, {},
                             jnp.asarray(0.5), 0.7, key)
        with pytest.raises(CT.ContractError, match="p_s"):
            SEL.select_masks({"fc": jnp.ones((2, 16))}, {},
                             jnp.asarray(0.5), 1.7, key)
        with pytest.raises(CT.ContractError, match="unit_mask"):
            ops.masked_dense(jnp.ones((2, 8)), jnp.ones((8, 4)),
                             jnp.ones((3,)))
        with pytest.raises(CT.ContractError, match="flash_attention"):
            ops.flash_attention(jnp.ones((1, 2, 8, 4)),
                                jnp.ones((1, 2, 6, 4)),
                                jnp.ones((1, 2, 6, 4)), causal=True)


def test_begin_cycle_contract():
    """begin_cycle's post: Eq. 2 masks obey the volume and the PRNG key
    advances; a stuck key is rejected."""
    schema = {"fc": (2, 16)}
    hcfg = HeliosConfig()
    state = ST.init_state(schema, volume=0.5, seed=3)
    with CT.override(True):
        CT.reset_counters()
        out = ST.begin_cycle(state, hcfg)
        assert CT.counters["mask_checks"] >= 1
        assert not bool(jnp.all(out["rng"] == state["rng"]))
        stuck = {**ST.init_state(schema, volume=1.0, seed=3)}
        with pytest.raises(CT.ContractError, match="rng key not advanced"):
            ST._begin_cycle_post(dict(stuck), stuck, hcfg)


# ---------------------------------------------------------------------------
# layer 2: domain checkers (valid + corrupted)
# ---------------------------------------------------------------------------


def _block_mask(L, n, block, P, seed=0):
    """A valid Eq. 2-style mask: block-constant rows with
    clip(round(P*nb), 1, nb) selected blocks each."""
    nb = -(-n // block)
    k = int(np.clip(np.round(np.float32(P) * nb), 1, nb))
    rng = np.random.default_rng(seed)
    rows = np.zeros((L, nb), np.float32)
    for i in range(L):
        rows[i, rng.choice(nb, size=k, replace=False)] = 1.0
    return np.repeat(rows, block, axis=-1)[:, :n]


def test_mask_checker_valid_and_corrupted():
    P, block = 0.5, 4
    m = _block_mask(3, 30, block, P)                 # ragged tail block
    with CT.override(True):
        CT.check_mask_invariants({"fc": m}, volume=P, block=block)
        CT.check_mask_invariants({"fc": m}, volume=None, block=block)

        broken = m.copy()
        broken[0, 0] = 1.0 - broken[0, 0]            # break block-constancy
        with pytest.raises(CT.ContractError, match="block-constant"):
            CT.check_mask_invariants({"fc": broken}, block=block)

        frac = m.copy()
        frac[0, 0] = 0.5                             # non-binary value
        with pytest.raises(CT.ContractError, match="outside"):
            CT.check_mask_invariants({"fc": frac}, block=block)

        with pytest.raises(CT.ContractError, match="selected counts"):
            CT.check_mask_invariants({"fc": np.ones((3, 30), np.float32)},
                                     volume=0.25, block=block)
    with CT.override(False):
        CT.check_mask_invariants({"fc": frac}, block=block)   # off: no-op


def test_staleness_checker():
    with CT.override(True):
        CT.check_staleness([0, 1, 3, 7], a=0.5)
        s = np.asarray([0.0, 2.0, 5.0])
        CT.check_staleness(s, weights=(s + 1.0) ** -0.5, a=0.5)
        with pytest.raises(CT.ContractError, match="negative staleness"):
            CT.check_staleness([1.0, -2.0])
        with pytest.raises(CT.ContractError, match="diverge"):
            CT.check_staleness(s, weights=[1.0, 1.0, 1.0], a=0.5)


def test_ring_and_snapshot_checkers():
    def alloc(misses=0, live=2, slots=5, peak=3):
        return types.SimpleNamespace(anchor_misses=misses, slots=slots,
                                     live_slots=lambda: live,
                                     peak_live=peak)
    with CT.override(True):
        CT.check_ring(alloc(), n_clients=8)
        with pytest.raises(CT.ContractError, match="evicted"):
            CT.check_ring(alloc(misses=1), n_clients=8)
        with pytest.raises(CT.ContractError, match="exceed"):
            CT.check_ring(alloc(live=5), n_clients=8)
        with pytest.raises(CT.ContractError, match="peak"):
            CT.check_ring(alloc(peak=9), n_clients=8)
        CT.check_snapshot_bound(6, 0, cap=4, n_clients=4)
        with pytest.raises(CT.ContractError, match="peak"):
            CT.check_snapshot_bound(20, 0, cap=4, n_clients=4)


def test_compile_budget_checker():
    class FakeFn:
        def __init__(self, n):
            self.n = n

        def _cache_size(self):
            return self.n

    run = types.SimpleNamespace(_local_train=FakeFn(1), _eval_chunk=FakeFn(2),
                                _round_cache={("h", 4): FakeFn(1)},
                                _bucket_cache={4: FakeFn(1)})
    with CT.override(True):
        CT.check_compile_budget(run)
        rep = CT.compile_report(run)
        assert rep["local_train"] == 1 and rep["bucket"] == {4: 1}
        run._round_cache[("h", 2)] = FakeFn(3)       # one signature, 3 progs
        with pytest.raises(CT.ContractError, match="compile budget"):
            CT.check_compile_budget(run)


def test_counters_zero_when_off():
    """Zero-overhead claim: with contracts off no guard installs, no
    counter ticks, no checker raises."""
    CT.reset_counters()
    with CT.override(False):
        with CT.no_host_transfers("x"):
            float(jnp.ones(()))
        CT.assert_finite({"a": jnp.array([jnp.nan])})
        CT.check_staleness([-1.0])
        CT.check_mask_invariants({"fc": np.full((1, 8), 0.5)})
        CT.check_compile_budget(types.SimpleNamespace())
    assert all(v == 0 for v in CT.counters.values()), CT.counters


# ---------------------------------------------------------------------------
# layer 2: contracts on the real engines
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setting():
    cfg = reduced(CNNS["lenet"])
    imgs, labels = class_gaussian_images(800, cfg.image_size,
                                         cfg.in_channels, cfg.num_classes,
                                         seed=0)
    ti, tl = class_gaussian_images(128, cfg.image_size, cfg.in_channels,
                                   cfg.num_classes, seed=9)
    parts = partition_noniid(labels, 4, shards_per_client=4)
    return cfg, imgs, labels, ti, tl, parts


def _make(setting, cls, scheme, **kw):
    cfg, imgs, labels, ti, tl, parts = setting
    clients = setup_clients(make_fleet(2, 2), parts, HeliosConfig())
    return cls(cfg, HeliosConfig(), scheme, clients,
               {"images": imgs, "labels": labels},
               {"images": ti, "labels": tl},
               local_steps=1, batch_size=8, lr=0.1, seed=0,
               eval_batch=64, **kw)


def test_engine_guard_catches_injected_sync(setting):
    """A per-round ``float(loss)`` smuggled into the guarded train section
    is exactly the hazard the transfer guard exists for."""
    class LeakyFLRun(FLRun):
        def _train_cohort(self, cohort, cclients):
            losses, ratios = super()._train_cohort(cohort, cclients)
            float(losses[0])                     # implicit d2h sync
            return losses, ratios

    leaky = _make(setting, LeakyFLRun, "helios")
    with CT.override(True):
        with pytest.raises(CT.ContractError, match="run_sync"):
            leaky.run_sync(1, eval_every=0)


def test_batched_engine_contracts_on_partial_participation(setting):
    """ISSUE acceptance: contracts-enabled run over >=3 distinct sampled
    cohorts — transfer guard + finite/mask checks + the <=1 program per
    shape-signature compile budget all hold on the real engine."""
    run = _make(setting, BatchedFLRun, "helios", participation=2)
    with CT.override(True):
        CT.reset_counters()
        hist = run.run_sync(4)
    assert len(hist) == 4
    assert len({tuple(c) for c in run.cohort_log}) > 1   # draws varied
    assert CT.counters["guarded_sections"] >= 4
    assert CT.counters["finite_checks"] >= 4
    assert CT.counters["compile_checks"] >= 1
    assert CT.counters["blocked_transfers"] == 0
    rep = CT.compile_report(run)
    assert rep.get("round"), rep
    with CT.override(True):
        CT.check_compile_budget(run)
    # same engine, contracts off: trajectory unchanged (guards are inert)
    ref = _make(setting, BatchedFLRun, "helios", participation=2)
    with CT.override(False):
        href = ref.run_sync(4)
    for a, b in zip(hist, href):
        np.testing.assert_allclose(a["ratios"], b["ratios"], atol=0)
        assert a["loss"] == b["loss"]


# ---------------------------------------------------------------------------
# hypothesis properties for the checkers
# ---------------------------------------------------------------------------


if HAVE_HYP:
    @needs_hyp
    @settings(max_examples=40, deadline=None)
    @given(st.integers(1, 4), st.integers(4, 8), st.integers(1, 3),
           st.floats(0.05, 1.0), st.integers(0, 10**6), st.data())
    def test_prop_mask_checker(block, nb, L, P, seed, data):
        """Any block-constant mask with clip(round(P*nb),1,nb) blocks per
        row passes; flipping one unit inside a multi-unit block breaks
        block-constancy and is rejected."""
        n = data.draw(st.integers(nb * block - block + 1, nb * block))
        m = _block_mask(L, n, block, P, seed=seed)
        with CT.override(True):
            CT.check_mask_invariants({"u": m}, volume=P, block=block,
                                     slack=0)
            if block > 1 and n >= 4 * block:
                row = data.draw(st.integers(0, L - 1))
                col = data.draw(st.integers(0, min(n, block) - 1))
                bad = m.copy()
                bad[row, col] = 1.0 - bad[row, col]
                with pytest.raises(CT.ContractError):
                    CT.check_mask_invariants({"u": bad}, block=block)

    @needs_hyp
    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.floats(0.0, 1e6), min_size=1, max_size=16),
           st.floats(0.1, 2.0))
    def test_prop_staleness_checker(stales, a):
        """(s+1)^-a weights of any non-negative staleness list are in
        (0, 1], monotone, and accepted; a negative staleness never is."""
        with CT.override(True):
            s = np.asarray(stales)
            CT.check_staleness(s, weights=(s + 1.0) ** (-a), a=a)
            with pytest.raises(CT.ContractError):
                CT.check_staleness(np.concatenate([s, [-1.0]]), a=a)
