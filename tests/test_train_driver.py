"""The training driver end-to-end: loss improves, checkpoints restart."""
import jax

from repro.launch.train import main as train_main


def test_train_improves_and_resumes(tmp_path):
    ckpt = str(tmp_path / "run")
    losses = train_main([
        "--arch", "xlstm-125m", "--reduced", "--steps", "14", "--batch", "8",
        "--seq", "64", "--lr", "3e-3", "--volume", "0.75",
        "--ckpt-dir", ckpt, "--ckpt-every", "7", "--log-every", "100"])
    assert len(losses) == 14

    # restart: picks up at step 14 (checkpointed at the end) and continues
    losses2 = train_main([
        "--arch", "xlstm-125m", "--reduced", "--steps", "16", "--batch", "8",
        "--seq", "64", "--lr", "3e-3", "--volume", "0.75",
        "--ckpt-dir", ckpt, "--ckpt-every", "7", "--log-every", "100"])
    assert len(losses2) == 2                 # only steps 14..15 re-run


def test_helios_volume_reduces_masked_fraction():
    """volume < 1 -> the train step's Helios masks are actually partial."""
    from repro.configs import ARCHS, HeliosConfig, TrainConfig, reduced
    from repro.core import soft_train as ST
    from repro.launch import steps as S

    cfg = reduced(ARCHS["deepseek-7b"])
    hcfg = HeliosConfig(enabled=True, contribution="grad_ema")
    tcfg = TrainConfig(total_steps=10)
    state = S.init_train_state(jax.random.PRNGKey(0), cfg, hcfg, tcfg)
    state["helios"] = ST.set_volume(state["helios"], 0.5)
    state["helios"] = ST.begin_cycle(state["helios"], hcfg)
    fracs = [float(m.mean()) for m in state["helios"]["masks"].values()]
    assert all(0.3 < f < 0.7 for f in fracs), fracs
