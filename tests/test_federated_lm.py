"""Federated LM through the family-adapter seam.

The round engines must be family-blind: a dense transformer federates on
Non-IID Markov-topic token streams with the SAME engines that run the CNN
testbed, and the batched engine replays the sequential trajectory for a
fixed seed (params atol 1e-5, matching selected fractions and volumes).
"""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, HeliosConfig, reduced
from repro.data.federated import label_distribution, partition_by_topic
from repro.data.synthetic import markov_topic_tokens
from repro.federated import (BatchedFLRun, FLRun, TokenLMAdapter,
                             make_adapter, make_fleet, setup_clients)

N_TOPICS = 8
DATA_VOCAB = 64          # << model vocab: CE falls measurably in ~3 rounds


@pytest.fixture(scope="module")
def lm_setting():
    cfg = reduced(ARCHS["deepseek-7b"])          # small dense transformer
    tokens, topics = markov_topic_tokens(240, 32, DATA_VOCAB,
                                         n_topics=N_TOPICS, seed=0)
    test_tokens, _ = markov_topic_tokens(64, 32, DATA_VOCAB,
                                         n_topics=N_TOPICS, seed=9)
    parts = partition_by_topic(topics, 4, topics_per_client=2)
    return cfg, {"tokens": tokens}, {"tokens": test_tokens}, parts, topics


def _make(lm_setting, cls, scheme, hcfg=None, local_steps=2, batch_size=4,
          lr=0.05, **kw):
    cfg, train, test, parts, _ = lm_setting
    hcfg = hcfg or HeliosConfig()
    clients = setup_clients(make_fleet(2, 2), parts, hcfg)
    return cls(cfg, hcfg, scheme, clients, train, test,
               local_steps=local_steps, batch_size=batch_size, lr=lr,
               seed=0, eval_batch=48, **kw)


def _max_param_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                   - np.asarray(y, np.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("scheme", ["helios", "syn", "st_only"])
def test_lm_batched_matches_sequential(lm_setting, scheme):
    """Fixed seed, 3 rounds: same global params (atol 1e-5), same straggler
    selected fractions, same adapted volumes — for a TOKEN family."""
    seq = _make(lm_setting, FLRun, scheme)
    bat = _make(lm_setting, BatchedFLRun, scheme)
    hs = seq.run_sync(3)
    hb = bat.run_sync(3)
    assert _max_param_diff(seq.global_params, bat.global_params) < 1e-5
    for he, hbb in zip(hs, hb):
        np.testing.assert_allclose(he["ratios"], hbb["ratios"], atol=1e-6)
        np.testing.assert_allclose(he["volumes"], hbb["volumes"], atol=1e-6)
        assert abs(he["time"] - hbb["time"]) < 1e-9
        assert abs(he["ce"] - hbb["ce"]) < 1e-4


def test_lm_masked_mean_generic_expansion(lm_setting):
    """The generic (logical-axes) stacked mask expansion matches the
    sequential list-of-pytrees masked-mean path."""
    hcfg = HeliosConfig(aggregation="masked_mean")
    seq = _make(lm_setting, FLRun, "helios", hcfg=hcfg)
    bat = _make(lm_setting, BatchedFLRun, "helios", hcfg=hcfg)
    seq.run_sync(2)
    bat.run_sync(2)
    assert _max_param_diff(seq.global_params, bat.global_params) < 1e-5


def test_lm_learns_below_uniform(lm_setting):
    """CE must fall well below the model's uniform baseline ln(vocab) —
    the soft-training path really trains the transformer."""
    cfg, *_ = lm_setting
    run = _make(lm_setting, BatchedFLRun, "helios", local_steps=4,
                batch_size=8, lr=0.5)
    hist = run.run_sync(3)
    uniform = float(np.log(cfg.vocab_size))                  # ~5.55 at init
    assert hist[-1]["ce"] < uniform - 0.5, hist
    assert hist[-1]["ce"] < hist[0]["ce"]


def test_lm_straggler_masks_partial(lm_setting):
    """Straggler LM clients hold genuinely compressed unit masks over the
    axis-driven schema (heads / mlp)."""
    run = _make(lm_setting, FLRun, "helios")
    run.run_sync(2)
    for c in run.clients:
        if c.is_straggler:
            assert set(c.helios_state["masks"]) == {"heads", "mlp"}
            fracs = [float(m.mean()) for m in c.helios_state["masks"].values()]
            assert min(fracs) < 0.9, fracs


def test_partition_by_topic_skew(lm_setting):
    """Each client's corpus concentrates on a few topics (Non-IID): the
    top-2 topics hold most of its documents, and nobody sees all topics."""
    *_, parts, topics = lm_setting
    hist = label_distribution(topics, parts, N_TOPICS)
    covered = (hist > 0).sum(axis=1)
    assert covered.max() < N_TOPICS
    top2 = np.sort(hist, axis=1)[:, -2:].sum(axis=1)
    assert (top2 / hist.sum(axis=1) >= 0.6).all(), hist
    assert sorted(np.concatenate(parts).tolist()) == list(range(len(topics)))


def test_adapter_dispatch_and_unsupported_family():
    cfg = reduced(ARCHS["deepseek-7b"])
    assert isinstance(make_adapter(cfg), TokenLMAdapter)
    encdec = reduced(ARCHS["seamless-m4t-large-v2"])
    with pytest.raises(NotImplementedError):
        make_adapter(encdec)
