"""Property-based tests for the Eq. 2 selection + rotation regulation.

Randomized sweeps over (rows, units, volume, P_s, forced sets, PRNG seeds)
pin the selection invariants the engines rely on:

* masks are EXACTLY 0/1 (the masked training path multiplies by them);
* every row selects exactly ``clip(round(P*n), 1, n)`` units — the traced
  count the adaptive volume controller assumes;
* forced (rotation-regulated) units preempt the draw whenever they fit in
  the budget — "pull the long-term skipped neurons back to training";
* the auto rotation threshold 1 + 1/P is monotone in 1/P.

Requires hypothesis (importorskip, like tests/test_theory_property.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection as S

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

SHAPES = st.tuples(st.integers(1, 3), st.integers(2, 48))


@settings(max_examples=25, deadline=None)
@given(shape=SHAPES,
       volume=st.floats(0.05, 1.0),
       p_s=st.floats(0.0, 1.0),
       forced_frac=st.floats(0.0, 1.0),
       seed=st.integers(0, 2 ** 16))
def test_masks_binary_and_exact_count(shape, volume, p_s, forced_frac,
                                      seed):
    L, n = shape
    rng = np.random.default_rng(seed)
    scores = {"u": jnp.asarray(rng.normal(size=(L, n)), jnp.float32)}
    nf = int(round(forced_frac * n))
    f = np.zeros((L, n), bool)
    f[:, :nf] = True
    masks = S.select_masks(scores, {"u": jnp.asarray(f)},
                           jnp.float32(volume), p_s,
                           jax.random.PRNGKey(seed))
    m = np.asarray(masks["u"])
    assert set(np.unique(m)) <= {0.0, 1.0}
    k_total = int(np.clip(round(volume * n), 1, n))
    np.testing.assert_array_equal(m.sum(axis=1),
                                  np.full(L, k_total, np.float32))


@settings(max_examples=25, deadline=None)
@given(shape=SHAPES,
       volume=st.floats(0.05, 1.0),
       p_s=st.floats(0.0, 1.0),
       seed=st.integers(0, 2 ** 16),
       data=st.data())
def test_forced_units_always_selected(shape, volume, p_s, seed, data):
    """Any forced set that fits in the round(P*n) budget is fully selected,
    no matter how low its contribution scores."""
    L, n = shape
    k_total = int(np.clip(round(volume * n), 1, n))
    nf = data.draw(st.integers(0, k_total))
    rng = np.random.default_rng(seed)
    scores = {"u": jnp.asarray(rng.normal(size=(L, n)), jnp.float32)}
    f = np.zeros((L, n), bool)
    # forced units get the WORST scores: selection must still take them
    order = np.argsort(np.asarray(scores["u"]), axis=1)
    for r in range(L):
        f[r, order[r, :nf]] = True
    masks = S.select_masks(scores, {"u": jnp.asarray(f)},
                           jnp.float32(volume), p_s,
                           jax.random.PRNGKey(seed))
    m = np.asarray(masks["u"])
    assert np.all(m[f] == 1.0)


@settings(max_examples=50, deadline=None)
@given(v1=st.floats(1e-3, 1.0), v2=st.floats(1e-3, 1.0))
def test_rotation_threshold_monotone_in_inverse_volume(v1, v2):
    """threshold = 1 + 1/P: a smaller volume always implies an equal or
    larger rotation threshold (slower forced rotation for tiny submodels)."""
    lo, hi = sorted([v1, v2])
    t_lo = float(S.rotation_threshold(jnp.float32(lo)))
    t_hi = float(S.rotation_threshold(jnp.float32(hi)))
    assert t_lo >= t_hi
    assert t_hi >= 2.0 - 1e-5                     # 1 + 1/P >= 2 for P <= 1
    # fixed mode ignores the volume entirely
    assert float(S.rotation_threshold(jnp.float32(lo), auto=False,
                                      fixed=7)) == 7.0
