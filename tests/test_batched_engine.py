"""Batched round engine: equivalence against the sequential reference,
checkpointing through the stacked state, and the all-straggler pace guard.

The batched engine must be a pure data-layout change: for a fixed seed it
replays the sequential engine's trajectory (same batches, same PRNG-driven
mask selection, same volume adaptation) up to batched-reduction float error.
"""
import jax
import numpy as np
import pytest

import repro.checkpoint.checkpoint as CKPT
from repro.checkpoint import restore, save
from repro.configs import CNNS, HeliosConfig, reduced
from repro.core import soft_train as ST
from repro.data.federated import partition_noniid
from repro.data.synthetic import class_gaussian_images
from repro.federated import (BatchedFLRun, FLRun, TABLE_I, Client,
                             make_fleet, setup_clients)


@pytest.fixture(scope="module")
def setting():
    cfg = reduced(CNNS["lenet"])
    imgs, labels = class_gaussian_images(1200, cfg.image_size,
                                         cfg.in_channels, cfg.num_classes,
                                         seed=0)
    ti, tl = class_gaussian_images(256, cfg.image_size, cfg.in_channels,
                                   cfg.num_classes, seed=9)
    parts = partition_noniid(labels, 4, shards_per_client=4)
    return cfg, imgs, labels, ti, tl, parts


def _make(setting, cls, scheme, hcfg=None, **kw):
    cfg, imgs, labels, ti, tl, parts = setting
    hcfg = hcfg or HeliosConfig()
    clients = setup_clients(make_fleet(2, 2), parts, hcfg)
    return cls(cfg, hcfg, scheme, clients,
               {"images": imgs, "labels": labels},
               {"images": ti, "labels": tl},
               local_steps=2, lr=0.1, seed=0, **kw)


def _max_param_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                   - np.asarray(y, np.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("scheme", ["helios", "syn", "st_only", "random"])
def test_batched_matches_sequential(setting, scheme):
    """Fixed seed, 3 rounds: same global params (atol 1e-5), same per-round
    straggler selected fractions, same adapted volumes."""
    seq = _make(setting, FLRun, scheme)
    bat = _make(setting, BatchedFLRun, scheme)
    hs = seq.run_sync(3)
    hb = bat.run_sync(3)
    assert _max_param_diff(seq.global_params, bat.global_params) < 1e-5
    for he, hbb in zip(hs, hb):
        np.testing.assert_allclose(he["ratios"], hbb["ratios"], atol=1e-6)
        np.testing.assert_allclose(he["volumes"], hbb["volumes"], atol=1e-6)
        assert abs(he["time"] - hbb["time"]) < 1e-9


def test_batched_masked_mean_aggregation(setting):
    """The stacked per-coordinate masked mean matches the list-of-pytrees
    reference path."""
    hcfg = HeliosConfig(aggregation="masked_mean")
    seq = _make(setting, FLRun, "helios", hcfg=hcfg)
    bat = _make(setting, BatchedFLRun, "helios", hcfg=hcfg)
    seq.run_sync(2)
    bat.run_sync(2)
    assert _max_param_diff(seq.global_params, bat.global_params) < 1e-5


def test_batched_state_sync_and_elastic(setting):
    """Stacked state writes back to clients; add/remove re-jits cohorts."""
    cfg, *_, parts = setting
    bat = _make(setting, BatchedFLRun, "helios")
    bat.run_sync(2)
    bat.sync_client_states()
    for c in bat.clients:
        if c.is_straggler:
            assert int(c.helios_state["cycle"]) == 2
            fracs = [float(m.mean()) for m in c.helios_state["masks"].values()]
            assert min(fracs) < 0.9                       # compressed
    n0 = len(bat.clients)
    new = bat.add_client(TABLE_I[0], parts[0])
    assert new.is_straggler and len(bat.clients) == n0 + 1
    bat.run_sync(1)
    bat.remove_client(new.cid)
    assert len(bat.clients) == n0
    bat.run_sync(1)                                       # still trains


def test_batched_elastic_states_match_sequential(setting):
    """add_client/remove_client mid-run round-trips sync_client_states ->
    restack without corrupting straggler masks/scores: after identical churn
    both engines hold identical per-client Helios state."""
    cfg, *_, parts = setting
    seq = _make(setting, FLRun, "helios")
    bat = _make(setting, BatchedFLRun, "helios")
    seq.run_sync(2)
    bat.run_sync(2)
    ns = seq.add_client(TABLE_I[0], parts[0])
    nb = bat.add_client(TABLE_I[0], parts[0])
    assert (ns.cid, ns.is_straggler) == (nb.cid, nb.is_straggler)
    seq.run_sync(2)
    bat.run_sync(2)
    drop = [c.cid for c in seq.clients if c.is_straggler][0]
    seq.remove_client(drop)
    bat.remove_client(drop)
    seq.run_sync(1)
    bat.run_sync(1)
    bat.sync_client_states()
    assert [c.cid for c in seq.clients] == [c.cid for c in bat.clients]
    for cs, cb in zip(seq.clients, bat.clients):
        assert cs.is_straggler == cb.is_straggler
        np.testing.assert_allclose(cs.volume, cb.volume, atol=1e-6)
        for key in ("masks", "skip_counts", "cycle", "rng"):
            for a, b in zip(jax.tree.leaves(cs.helios_state[key]),
                            jax.tree.leaves(cb.helios_state[key])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(cs.helios_state["scores"]),
                        jax.tree.leaves(cb.helios_state["scores"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4)


def test_round_cache_lru_bounded(setting):
    """Elastic churn across many distinct cohort shapes must not grow the
    compiled-program cache without limit."""
    cfg, *_, parts = setting
    bat = _make(setting, BatchedFLRun, "helios")
    bat.round_cache_cap = 2
    added = []
    for i in range(3):
        c = bat.add_client(TABLE_I[i % len(TABLE_I)],
                           parts[i % len(parts)])
        added.append(c.cid)
        assert len(bat._round_cache) <= 2
    for cid in added:
        bat.remove_client(cid)
        assert len(bat._round_cache) <= 2
    bat.run_sync(1)                               # still trains post-eviction


def test_all_straggler_pace_is_finite(setting):
    """Regression: an all-straggler cohort used to propagate a NaN
    collaboration pace (truthy NaN median) into volume adaptation."""
    cfg, imgs, labels, ti, tl, parts = setting
    hcfg = HeliosConfig()
    clients = [Client(cid=i, profile=TABLE_I[i % len(TABLE_I)],
                      data_idx=parts[i % len(parts)], volume=0.5,
                      is_straggler=True) for i in range(2)]
    run = FLRun(cfg, hcfg, "helios", clients,
                {"images": imgs, "labels": labels},
                {"images": ti, "labels": tl},
                local_steps=1, lr=0.1, seed=0)
    hist = run.run_sync(2)
    for c in run.clients:
        assert np.isfinite(c.volume)
        assert hcfg.min_volume <= c.volume <= 1.0
    assert np.isfinite(hist[-1]["time"])


def test_checkpoint_zlib_fallback_roundtrip(setting, tmp_path, monkeypatch):
    """FL state survives save/restore through the no-zstandard path, and the
    file header records the zlib codec flag."""
    monkeypatch.setattr(CKPT, "_HAVE_ZSTD", False)
    bat = _make(setting, BatchedFLRun, "helios")
    bat.run_sync(1)
    bat.sync_client_states()
    state = {"global": bat.global_params,
             "helios": [c.helios_state for c in bat.clients]}
    path = save(str(tmp_path), 7, state, metadata={"engine": "batched"})
    with open(path, "rb") as f:
        head = f.read(5)
    assert head == CKPT._MAGIC + CKPT._CODEC_ZLIB
    restored, step = restore(str(tmp_path), state)
    assert step == 7
    assert _max_param_diff(state["global"], restored["global"]) == 0.0
    for a, b in zip(jax.tree.leaves(state["helios"]),
                    jax.tree.leaves(restored["helios"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stack_unstack_roundtrip():
    schema = {"conv0": (1, 6), "fc0": (1, 12)}
    states = [ST.init_state(schema, volume=0.5 + 0.1 * i, seed=i)
              for i in range(3)]
    stacked = ST.stack_states(states)
    assert stacked["volume"].shape == (3,)
    back = ST.unstack_states(stacked, 3)
    for orig, rt in zip(states, back):
        for a, b in zip(jax.tree.leaves(orig), jax.tree.leaves(rt)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    restamped = ST.set_volumes(stacked, [0.2, 0.3, 0.4])
    np.testing.assert_allclose(np.asarray(restamped["volume"]),
                               [0.2, 0.3, 0.4])
