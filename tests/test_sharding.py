"""Sharding rule engine: divisibility fallbacks, cache specs, batch specs."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.parallel import sharding as SH


@pytest.fixture(scope="module")
def mesh():
    # AbstractMesh: the 16x16 production topology without real devices.
    # Newer JAX takes (sizes, names); 0.4.x takes ((name, size), ...) pairs.
    try:
        return jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    except TypeError:
        return jax.sharding.AbstractMesh(
            (("data", 16), ("model", 16)))


def test_spec_divisible(mesh):
    # default rules: FSDP on embed (data axis) + TP on mlp (model axis)
    spec = SH.spec_for_axes(("embed", "mlp"), (64, 128 * 16), mesh)
    assert spec == P("data", "model")
    # indivisible embed replicates
    spec2 = SH.spec_for_axes(("embed", "mlp"), (50, 128 * 16), mesh)
    assert spec2 == P(None, "model")


def test_spec_fallback_indivisible(mesh):
    # 14 heads don't divide the 16-way model axis -> replicate (internvl2)
    spec = SH.spec_for_axes(("embed", "heads", "head_dim"), (896, 14, 64),
                            mesh)
    assert spec[1] is None


def test_internvl2_mlp_still_shards(mesh):
    # d_ff = 4864 = 16*304 -> tensor-sharded even though heads replicate
    spec = SH.spec_for_axes(("embed", "mlp"), (896, 4864), mesh)
    assert spec[1] == "model"


def test_no_axis_reuse_within_tensor(mesh):
    spec = SH.spec_for_axes(("mlp", "heads"), (128 * 16, 16 * 16), mesh,
                            rules={"mlp": ("model",), "heads": ("model",)})
    assert spec == P("model", None)


def test_rules_for_small_vs_large():
    small = SH.rules_for(ARCHS["xlstm-125m"])
    big = SH.rules_for(ARCHS["qwen2.5-32b"])
    assert small["embed"] == ()
    assert big["embed"] == ("data",)


def test_cache_spec_batch_then_kv(mesh):
    spec = SH.cache_spec((128, 1024, 32, 64), mesh, batch=128, seq=1024,
                         kv_heads=32)
    assert spec[0] is not None
    assert spec[2] == "model"


def test_cache_spec_gqa_fallback_seq_model(mesh):
    # kv=8 < 16-way model axis -> cache sequence absorbs "model"
    spec = SH.cache_spec((128, 32768, 8, 64), mesh, batch=128, seq=32768,
                         kv_heads=8)
    assert spec[1] == "model"


def test_cache_spec_long_context_seq_sharded(mesh):
    # batch=1 (long_500k): sequence takes the data axes
    spec = SH.cache_spec((1, 1024 * 16, 8, 64), mesh, batch=1,
                         seq=1024 * 16, kv_heads=8)
    assert spec[1] is not None


def test_batch_spec(mesh):
    spec = SH.batch_spec((256, 128), mesh, batch_size=256)
    assert spec[0] is not None


def test_batch_spec_indivisible_replicates(mesh):
    spec = SH.batch_spec((3, 128), mesh, batch_size=3)
    assert spec == P(None, None)
