"""Substrate tests: optimizers, compression, data, checkpoint, aggregation,
HLO cost model."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.core import aggregation as AG
from repro.data.federated import label_distribution, partition_iid, partition_noniid
from repro.data.synthetic import batches, class_gaussian_images, markov_tokens
from repro.optim import (adamw, apply_updates, clip_by_global_norm,
                         compression, global_norm, momentum, sgd,
                         warmup_cosine_schedule)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def test_sgd_step():
    opt = sgd(0.1)
    p = {"w": jnp.ones(3)}
    g = {"w": jnp.ones(3)}
    u, _ = opt.update(g, opt.init(p), p, 0)
    np.testing.assert_allclose(np.asarray(apply_updates(p, u)["w"]), 0.9)


def test_momentum_accumulates():
    opt = momentum(1.0, beta=0.5)
    p = {"w": jnp.zeros(1)}
    s = opt.init(p)
    g = {"w": jnp.ones(1)}
    u1, s = opt.update(g, s, p, 0)
    u2, s = opt.update(g, s, p, 1)
    assert float(u2["w"][0]) == -1.5                     # 1 + 0.5*1


def test_adamw_decays_matrices_not_vectors():
    opt = adamw(0.1, weight_decay=1.0)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones(2)}
    u, _ = opt.update({"w": jnp.zeros((2, 2)), "b": jnp.zeros(2)},
                      opt.init(p), p, 0)
    assert float(jnp.abs(u["w"]).max()) > 0.0            # decay applied
    assert float(jnp.abs(u["b"]).max()) == 0.0           # vectors exempt


def test_adamw_reduces_loss():
    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (8,))
    x = jax.random.normal(jax.random.fold_in(key, 1), (64, 8))
    y = x @ w_true
    p = {"w": jnp.zeros(8)}
    opt = adamw(0.1)
    s = opt.init(p)
    loss = lambda p: jnp.mean((x @ p["w"] - y) ** 2)
    l0 = float(loss(p))
    for i in range(100):
        g = jax.grad(loss)(p)
        u, s = opt.update(g, s, p, i)
        p = apply_updates(p, u)
    assert float(loss(p)) < 0.05 * l0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == 20.0


def test_warmup_cosine():
    s = warmup_cosine_schedule(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) <= 0.11


# ---------------------------------------------------------------------------
# compression (refs [19][20])
# ---------------------------------------------------------------------------


def test_topk_compression_keeps_largest():
    g = {"w": jnp.asarray([0.1, -5.0, 0.2, 3.0])}
    err = compression.init_error(g)
    sparse, new_err, frac = compression.compress(g, err, 0.5)
    np.testing.assert_allclose(np.asarray(sparse["w"]), [0, -5.0, 0, 3.0])
    np.testing.assert_allclose(np.asarray(new_err["w"]), [0.1, 0, 0.2, 0])
    assert abs(float(frac) - 0.5) < 1e-6


def test_error_feedback_preserves_mass():
    """Over cycles, error feedback transmits everything eventually."""
    g = {"w": jnp.asarray([1.0, 0.01, 0.005, 0.001])}
    err = compression.init_error(g)
    sent = jnp.zeros(4)
    for _ in range(16):
        sparse, err, _ = compression.compress(g, err, 0.25)
        sent = sent + sparse["w"]
    # average transmitted signal approaches cumulative gradient
    np.testing.assert_allclose(np.asarray(sent / 16), np.asarray(g["w"]),
                               atol=0.02)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_noniid_partition_skew():
    _, labels = class_gaussian_images(1000, 8, 1, 10, seed=0)
    parts = partition_noniid(labels, 5, shards_per_client=2)
    dist = label_distribution(labels, parts, 10)
    # each client sees only a few classes
    classes_per_client = (dist > 0).sum(axis=1)
    assert classes_per_client.max() <= 4
    # every sample assigned exactly once
    assert sum(len(p) for p in parts) == 1000


def test_iid_partition_covers():
    parts = partition_iid(100, 4)
    assert sorted(np.concatenate(parts).tolist()) == list(range(100))


def test_markov_tokens_learnable():
    toks = markov_tokens(4, 128, vocab=64, branching=4)
    assert toks.shape == (4, 128) and toks.max() < 64
    # successor entropy is low: repeated prefix pairs recur
    pairs = set(zip(toks[:, :-1].ravel(), toks[:, 1:].ravel()))
    assert len(pairs) < 64 * 16


def test_batches_iterator():
    xs = np.arange(10)
    it = batches((xs,), 3, epochs=2)
    seen = [b[0] for b in it]
    assert len(seen) == 6 and all(len(b) == 3 for b in seen)


# ---------------------------------------------------------------------------
# checkpoint / fault tolerance
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "opt": [{"m": jnp.ones(3)}, {"v": jnp.zeros(3)}],
            "step": jnp.asarray(7, jnp.int32)}
    save(str(tmp_path), 7, tree, metadata={"arch": "lenet"})
    got, step = restore(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert int(got["step"]) == 7


def test_checkpoint_keep_n(tmp_path):
    tree = {"x": jnp.zeros(1)}
    for s in range(6):
        save(str(tmp_path), s, tree, keep=2)
    assert latest_step(str(tmp_path)) == 5
    files = [f for f in os.listdir(tmp_path) if f.endswith(".zst")]
    assert len(files) == 2


def test_checkpoint_restores_latest_after_crash(tmp_path):
    tree = {"x": jnp.asarray([1.0])}
    save(str(tmp_path), 1, tree)
    save(str(tmp_path), 2, {"x": jnp.asarray([2.0])})
    # simulate partial write of a newer checkpoint
    with open(os.path.join(tmp_path, "ckpt_3.msgpack.zst.tmp"), "wb") as f:
        f.write(b"garbage")
    got, step = restore(str(tmp_path), tree)
    assert step == 2 and float(got["x"][0]) == 2.0


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save(str(tmp_path), 0, {"x": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore(str(tmp_path), {"x": jnp.zeros(4)})


# ---------------------------------------------------------------------------
# aggregation (Eq. 10 + variants)
# ---------------------------------------------------------------------------


def test_alpha_weights_eq10():
    a = AG.alpha_weights([1.0, 0.5, 0.5])
    np.testing.assert_allclose(np.asarray(a), [0.5, 0.25, 0.25])


def test_aggregate_alpha():
    g = {"w": jnp.zeros(2)}
    c1 = {"w": jnp.ones(2)}
    c2 = {"w": jnp.full(2, 3.0)}
    out = AG.aggregate_alpha(g, [c1, c2], [1.0, 1.0])
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0)


def test_masked_mean_respects_coverage():
    g = {"w": jnp.asarray([10.0, 10.0])}
    c1 = {"w": jnp.asarray([1.0, 99.0])}
    m1 = {"w": jnp.asarray([1.0, 0.0])}
    c2 = {"w": jnp.asarray([3.0, 98.0])}
    m2 = {"w": jnp.asarray([1.0, 0.0])}
    out = AG.aggregate_masked_mean(g, [c1, c2], [m1, m2])
    # coord 0 averaged over both; coord 1 untouched (nobody trained it)
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0, 10.0])


def test_staleness_weight_decreases():
    assert AG.staleness_weight(0) == 1.0
    assert AG.staleness_weight(3) < AG.staleness_weight(1)


# ---------------------------------------------------------------------------
# trip-count-weighted HLO cost model
# ---------------------------------------------------------------------------


def test_hlo_weighted_cost_matches_unrolled():
    from repro.parallel.hlo_analysis import cost_analysis_dict
    from repro.parallel.hlo_cost import weighted_cost

    def unrolled(x, w):
        for _ in range(6):
            x = jnp.tanh(x @ w)
        return x

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        x, _ = jax.lax.scan(body, x, None, length=6)
        return x

    x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    cu = jax.jit(unrolled).lower(x, w).compile()
    cs = jax.jit(scanned).lower(x, w).compile()
    fu = weighted_cost(cu.as_text())["flops"]
    fs = weighted_cost(cs.as_text())["flops"]
    analytic = 6 * 2 * 64 * 256 * 256
    assert abs(fu - analytic) / analytic < 0.05
    assert abs(fs - analytic) / analytic < 0.05
    # XLA's own analysis under-counts the scanned program (the bug we fix)
    assert cost_analysis_dict(cs)["flops"] < 0.5 * fs
