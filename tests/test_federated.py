"""Integration tests for the FL runtime: all five schemes run and converge;
Helios beats Syn-FL on time-to-accuracy with stragglers; elastic scaling and
checkpoint/restart of FL state work."""
import pytest

from repro.checkpoint import restore, save
from repro.configs import CNNS, HeliosConfig, reduced
from repro.data.federated import partition_noniid
from repro.data.synthetic import class_gaussian_images
from repro.federated import (FLRun, TABLE_I, cycle_time, make_fleet,
                             setup_clients)


@pytest.fixture(scope="module")
def setting():
    cfg = reduced(CNNS["lenet"])
    imgs, labels = class_gaussian_images(1500, cfg.image_size,
                                         cfg.in_channels, cfg.num_classes,
                                         seed=0)
    ti, tl = class_gaussian_images(400, cfg.image_size, cfg.in_channels,
                                   cfg.num_classes, seed=9)
    parts = partition_noniid(labels, 4, shards_per_client=4)
    return cfg, imgs, labels, ti, tl, parts


def _run(setting, scheme, rounds=6, **kw):
    cfg, imgs, labels, ti, tl, parts = setting
    hcfg = HeliosConfig()
    clients = setup_clients(make_fleet(2, 2), parts, hcfg)
    run = FLRun(cfg, hcfg, scheme, clients,
                {"images": imgs, "labels": labels},
                {"images": ti, "labels": tl},
                local_steps=4, lr=0.1, **kw)
    if scheme in ("syn", "helios", "st_only", "random"):
        return run, run.run_sync(rounds)
    return run, run.run_async(rounds)


def test_straggler_identification_in_setup(setting):
    cfg, *_, parts = setting
    clients = setup_clients(make_fleet(2, 2), parts, HeliosConfig())
    stragglers = [c for c in clients if c.is_straggler]
    assert len(stragglers) == 2
    assert all(c.volume < 1.0 for c in stragglers)
    assert all(c.volume == 1.0 for c in clients if not c.is_straggler)


@pytest.mark.parametrize("scheme", ["syn", "helios", "st_only", "random",
                                    "asyn", "afo"])
def test_scheme_runs_and_learns(setting, scheme):
    _, hist = _run(setting, scheme, rounds=6)
    assert len(hist) >= 3
    assert hist[-1]["acc"] > 0.3, f"{scheme}: {hist[-1]}"


def test_helios_faster_round_time_than_syn(setting):
    """The paper's core claim: straggler compression shortens the cycle."""
    _, h_syn = _run(setting, "syn", rounds=3)
    _, h_hel = _run(setting, "helios", rounds=3)
    t_syn = h_syn[-1]["time"] / h_syn[-1]["cycle"]
    t_hel = h_hel[-1]["time"] / h_hel[-1]["cycle"]
    assert t_hel < 0.65 * t_syn, (t_hel, t_syn)   # ~2.5x in the paper


def test_helios_masks_actually_partial(setting):
    run, _ = _run(setting, "helios", rounds=2)
    stragglers = [c for c in run.clients if c.is_straggler]
    for c in stragglers:
        fracs = [float(m.mean()) for m in c.helios_state["masks"].values()]
        assert min(fracs) < 0.9, fracs           # compressed
    capable = [c for c in run.clients if not c.is_straggler][0]
    # capable devices train the full model
    assert capable.volume == 1.0


def test_elastic_add_remove(setting):
    cfg, imgs, labels, ti, tl, parts = setting
    run, _ = _run(setting, "helios", rounds=2)
    n0 = len(run.clients)
    new = run.add_client(TABLE_I[0], parts[0])
    assert len(run.clients) == n0 + 1
    assert new.is_straggler and new.volume < 1.0
    run.run_sync(1)                               # still trains with the newcomer
    run.remove_client(new.cid)
    assert len(run.clients) == n0


def test_async_anchor_survives_snapshot_eviction(setting):
    """Regression: the async engines evicted the OLDEST snapshot even while
    a live client was still anchored to it, silently rebasing that client on
    the current global params with a mislabeled staleness.  Anchored
    snapshots must survive eviction (run_async indexes them directly, so a
    wrongly-evicted anchor would KeyError here)."""
    run, _ = _run(setting, "afo", rounds=0)
    hist = run.run_async(8, snapshot_cap=1)
    assert len(hist) >= 4
    assert all(h["staleness"] >= 0 for h in hist)
    for c in run.clients:
        assert c.staleness_anchor >= 0


def test_evaluate_covers_full_test_set(setting):
    """evaluate() scores the WHOLE test set in jitted chunks; the chunked
    weighted mean equals the single-shot metric exactly."""
    run, _ = _run(setting, "syn", rounds=1)
    n_test = len(setting[4])
    run.eval_batch = n_test                       # one full-set chunk
    full = run.evaluate()
    run.eval_batch = 96                           # ragged chunking (96*4+16)
    chunked = run.evaluate()
    assert abs(full - chunked) < 1e-6


def test_fl_state_checkpoint_restart(setting, tmp_path):
    """Full FL server state (incl. Helios masks + skip counters) survives a
    simulated crash/restart."""
    run, _ = _run(setting, "helios", rounds=2)
    state = {"global": run.global_params,
             "helios": [c.helios_state for c in run.clients]}
    save(str(tmp_path), 2, state)
    # crash: new run from scratch, then restore
    run2, _ = _run(setting, "helios", rounds=0)
    restored, step = restore(str(tmp_path), {
        "global": run2.global_params,
        "helios": [c.helios_state for c in run2.clients]})
    assert step == 2
    run2.global_params = restored["global"]
    for c, h in zip(run2.clients, restored["helios"]):
        c.helios_state = h
    acc_before = run.evaluate()
    acc_after = run2.evaluate()
    assert abs(acc_before - acc_after) < 1e-6


def test_cycle_time_scales_with_volume():
    p = TABLE_I[0]
    assert cycle_time(p, 0.5) == 0.5 * cycle_time(p, 1.0)
