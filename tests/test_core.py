"""Unit tests for the Helios core: selection (Eq. 2), rotation (§VI.A),
contribution (Eq. 1), masking, volume control, identification."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import HeliosConfig
from repro.core import contribution as C
from repro.core import masking as MK
from repro.core import selection as S
from repro.core import soft_train as ST
from repro.core import volume as V
from repro.core.identification import (DeviceProfile, identify_resource_based,
                                       identify_time_based, time_cost_model)


def test_selection_counts():
    """Eq. 2: ~P*n units selected per row; top-P_s kept by contribution."""
    key = jax.random.PRNGKey(0)
    scores = {"mlp": jnp.arange(512, dtype=jnp.float32).reshape(2, 256)}
    forced = {"mlp": jnp.zeros((2, 256), bool)}
    masks = S.select_masks(scores, forced, jnp.asarray(0.25), p_s=0.2,
                           key=key)
    count = int(masks["mlp"][0].sum())
    assert abs(count - 64) <= 1, count
    # the k_top = 0.2*64 ~ 13 highest-score units must be selected
    k_top = int(round(0.2 * 64))
    top_idx = np.argsort(-np.asarray(scores["mlp"][0]))[:k_top]
    assert np.asarray(masks["mlp"][0])[top_idx].all()


def test_selection_rotates():
    """The random component changes across cycles (model integrity)."""
    scores = {"mlp": jnp.zeros((1, 256))}
    forced = {"mlp": jnp.zeros((1, 256), bool)}
    m1 = S.select_masks(scores, forced, jnp.asarray(0.3), 0.1,
                        jax.random.PRNGKey(1))["mlp"]
    m2 = S.select_masks(scores, forced, jnp.asarray(0.3), 0.1,
                        jax.random.PRNGKey(2))["mlp"]
    assert float(jnp.abs(m1 - m2).sum()) > 0


def test_forced_units_always_selected():
    scores = {"mlp": jnp.ones((1, 128))}
    forced = {"mlp": jnp.zeros((1, 128), bool).at[0, 7].set(True)}
    masks = S.select_masks(scores, forced, jnp.asarray(0.1), 0.1,
                           jax.random.PRNGKey(0))
    assert float(masks["mlp"][0, 7]) == 1.0


def test_rotation_threshold_and_counters():
    """C_s counts consecutive skips; threshold = 1 + 1/P (§VI.A)."""
    skip = {"mlp": jnp.array([[0, 3, 5]], jnp.int32)}
    masks = {"mlp": jnp.array([[1.0, 0.0, 0.0]])}
    new = S.update_skip_counts(skip, masks)
    np.testing.assert_array_equal(np.asarray(new["mlp"]), [[0, 4, 6]])
    thr = S.rotation_threshold(jnp.asarray(0.25))
    assert float(thr) == 5.0
    forced = S.forced_units(new, thr)
    np.testing.assert_array_equal(np.asarray(forced["mlp"]),
                                  [[False, False, True]])


def test_no_unit_starves_over_cycles():
    """Every unit joins at least once within a bounded number of cycles."""
    hcfg = HeliosConfig(p_s=0.1)
    schema = {"mlp": (1, 64)}
    st = ST.init_state(schema, volume=0.25, seed=0)
    ever = np.zeros(64, bool)
    for _ in range(25):
        st = ST.begin_cycle(st, hcfg)
        ever |= np.asarray(st["masks"]["mlp"][0]) > 0
        # constant scores: rotation comes from randomness + forced rejoin
        st = ST.end_cycle(st, {"mlp": jnp.ones((1, 64))}, hcfg)
    assert ever.all(), f"{(~ever).sum()} units never trained"


def test_contribution_eq1_is_param_delta():
    new = {"w": jnp.full((4, 8), 2.0)}
    old = {"w": jnp.zeros((4, 8))}
    d = C.delta(new, old)
    scores = C.unit_scores(d, {"w": ("embed", "mlp")}, {"mlp": (1, 8)})
    np.testing.assert_allclose(np.asarray(scores["mlp"]),
                               np.full((1, 8), 8.0))


def test_expand_masks_outer_product():
    params = {"wi": jnp.ones((2, 4, 6))}           # (E, d, ff)
    axes = {"wi": ("experts", "embed", "mlp")}
    masks = {"experts": jnp.array([[1.0, 0.0]]),
             "mlp": jnp.array([[1, 1, 0, 0, 1, 1]], jnp.float32)}
    out = MK.expand_masks(axes, masks, params)
    m = np.asarray(out["wi"])
    assert m[0, :, 0].all() and not m[1].any()
    assert (m[0, :, 2] == 0).all()


def test_expand_masks_batch_matches_per_client():
    """The generic stacked expansion is exactly a vmap of expand_masks."""
    params = {"wi": jnp.ones((2, 4, 6))}           # (E, d, ff)
    axes = {"wi": ("experts", "embed", "mlp")}
    stacked = {"experts": jnp.array([[[1.0, 0.0]], [[0.0, 1.0]]]),
               "mlp": jnp.ones((2, 1, 6), jnp.float32)}
    out = MK.expand_masks_batch(axes, stacked, params)
    assert np.asarray(out["wi"]).shape == (2, 2, 4, 6)
    for i in range(2):
        one = MK.expand_masks(
            axes, {k: v[i] for k, v in stacked.items()}, params)
        np.testing.assert_array_equal(np.asarray(out["wi"])[i],
                                      np.asarray(one["wi"]))


def test_selected_fraction():
    masks = {"a": jnp.array([[1.0, 0.0, 1.0, 0.0]])}
    assert float(MK.selected_fraction(masks)) == 0.5


def test_volume_controller_converges():
    """adapt_volume drives observed time to the deadline."""
    vol, speed = 1.0, 4.0                      # device 4x slower
    for _ in range(12):
        observed = speed * vol
        vol = V.adapt_volume(vol, observed, deadline=1.0)
    assert abs(speed * vol - 1.0) < 0.15, (vol, speed * vol)


def test_volume_from_profile():
    assert V.volume_from_profile(4.0, 1.0) == 0.25
    assert V.volume_from_profile(0.5, 1.0) == 1.0
    assert V.volume_from_profile(100.0, 1.0, min_volume=0.125) == 0.125


def test_assign_volume_levels():
    out = V.assign_volume_levels([1.0, 5.0, 2.0, 4.0], (0.25, 0.5, 0.75), 2)
    assert out[1] == 0.25 and out[3] == 0.5 and out[0] == 1.0 and out[2] == 1.0


def test_identification_paths_agree():
    devs = [DeviceProfile("fast", 25, 400, 8000, 100, 1.0),
            DeviceProfile("fast2", 25, 400, 8000, 100, 1.0),
            DeviceProfile("slow", 5, 100, 2000, 100, 3.0)]
    _, s_resource = identify_resource_based(100, 200, devs)
    _, s_time = identify_time_based(lambda d: None, 3,
                                    simulated_times=[1.0, 1.0, 3.0])
    assert s_resource == [2] and s_time == [2]


def test_time_cost_model_formula():
    d = DeviceProfile("x", compute_gflops=10, memory_mb=100,
                      mem_bandwidth=1000, net_bandwidth=50)
    te = time_cost_model(workload_gflop=20, memory_mb=100, dev=d)
    assert abs(te - (20 / 10 + 100 / 1000 + 100 / 50)) < 1e-9
