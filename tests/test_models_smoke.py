"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes + finiteness.  FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, CNNS, SMOKE_SHAPE, reduced
from repro.models import (build, default_runtime, init_params,
                          input_specs, make_full_masks)


def _concrete_batch(cfg, shape, key):
    specs = input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        kk = jax.random.fold_in(key, hash(k) % 2**31)
        if v.dtype == jnp.int32:
            hi = max(cfg.vocab_size, cfg.num_classes, 10)
            out[k] = jax.random.randint(kk, v.shape, 0, min(hi, 255))
        else:
            out[k] = jax.random.normal(kk, v.shape, v.dtype)
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train(arch):
    cfg = reduced(ARCHS[arch])
    api = build(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    rt = default_runtime(cfg, SMOKE_SHAPE)
    batch = _concrete_batch(cfg, SMOKE_SHAPE, key)
    masks = make_full_masks(cfg)

    loss, grads = jax.value_and_grad(
        lambda p: api.loss_fn(p, batch, cfg, rt, masks))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: grad norm not finite"
    assert float(gnorm) > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_prefill_decode(arch):
    cfg = reduced(ARCHS[arch])
    api = build(cfg)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    rt = default_runtime(cfg, SMOKE_SHAPE)
    batch = _concrete_batch(cfg, SMOKE_SHAPE, key)
    masks = make_full_masks(cfg)

    logits, cache = api.prefill_fn(params, batch, cfg, rt, masks)
    assert logits.shape == (SMOKE_SHAPE.global_batch, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: prefill logits"

    token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = api.decode_fn(params, token, cache, cfg, rt, masks)
    assert logits2.shape == (SMOKE_SHAPE.global_batch, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2))), f"{arch}: decode logits"


@pytest.mark.parametrize("name", sorted(CNNS))
def test_cnn_smoke(name):
    cfg = reduced(CNNS[name])
    api = build(cfg)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    batch = _concrete_batch(cfg, SMOKE_SHAPE, key)
    batch["labels"] = batch["labels"] % cfg.num_classes
    masks = make_full_masks(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: api.loss_fn(p, batch, cfg, None, masks))(params)
    assert np.isfinite(float(loss))
