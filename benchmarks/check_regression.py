"""CI regression gate: fresh ``--quick`` bench JSONs vs committed ones.

CI regenerates the quick benches, then runs::

    PYTHONPATH=src python -m benchmarks.check_regression

which loads each fresh ``BENCH_*.json`` next to its committed baseline
(``git show <ref>:<name>``) and gates on the invariants that survive the
quick/full scale gap — a quick run has fewer rounds than the committed
full table, so raw accuracies are NOT comparable; ratios, censuses, and
zero-counters are:

* ``BENCH_observability.json`` — the disarmed recorder emitted ZERO
  events on both sides (telemetry off is genuinely off); ``uplink_updates
  == downlink_updates`` in the armed summary (every uplink answered by a
  dense broadcast); ``overhead_frac`` below an absolute ceiling
  (``--max-overhead``, default 0.5 — CI wall clocks are noisy, so this
  catches blowups, not drift).  When the fresh and committed runs have
  the SAME round count, the ``repro.obs diff`` tolerances
  (final_metric 5%, sim/uplink/downlink 25%, scaled by ``--tol-scale``)
  gate too; otherwise that diff is printed but informational.
* ``BENCH_scheme_gauntlet.json`` — identical scheme set and per-scheme
  engine as committed; scaffold's uplink is 2x syn's within 15% (the
  control variates ride dense — the documented cost); every scheme moved
  bytes in BOTH directions (uplink_mb > 0, downlink_mb > 0).
* ``BENCH_contracts.json`` — every ``off``-mode counter is zero on both
  sides (contracts off is free), and any check family the committed
  ``on`` run exercised is still exercised fresh (check volume cannot
  silently collapse).
* ``BENCH_serve_traffic.json`` — the hot-swap invariants that survive
  any scale: exactly ONE compiled prefill + decode program across every
  swap (a recompile on swap is the bug the traced-params design
  exists to prevent), at least one swap observed and no more swaps than
  promotion decisions, and the served latency p99 finite.

A baseline missing from the ref (a brand-new bench) or a fresh file not
regenerated in this CI job is skipped with a note, never failed — the
gate only compares what exists on both sides.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from repro.obs import report

FILES = ("BENCH_observability.json", "BENCH_scheme_gauntlet.json",
         "BENCH_contracts.json", "BENCH_serve_traffic.json")


def committed_json(name: str, ref: str):
    """The baseline as committed at ``ref`` (None if absent there)."""
    out = subprocess.run(["git", "show", f"{ref}:{name}"],
                         capture_output=True, text=True)
    if out.returncode != 0:
        return None
    return json.loads(out.stdout)


def _fail(problems, msg):
    problems.append(msg)
    print(f"  FAIL {msg}")


def _ok(msg):
    print(f"  ok   {msg}")


def check_observability(fresh, base, problems, tol_scale, max_overhead):
    for side, d in (("fresh", fresh), ("committed", base)):
        ev = d["results"]["off"].get("events")
        if ev == 0:
            _ok(f"{side}: off-mode events == 0")
        else:
            _fail(problems, f"{side}: disarmed recorder buffered {ev} "
                            "events (telemetry off must be off)")
    counters = fresh.get("summary", {}).get("counters", {})
    up, down = counters.get("uplink_updates"), \
        counters.get("downlink_updates")
    if up == down and up:
        _ok(f"uplink_updates == downlink_updates == {up}")
    else:
        _fail(problems, f"uplink_updates={up} != downlink_updates={down}")
    ov = fresh.get("overhead_frac", 0.0)
    if ov <= max_overhead:
        _ok(f"overhead_frac={ov:+.3f} <= {max_overhead}")
    else:
        _fail(problems, f"overhead_frac={ov:+.3f} > {max_overhead}")
    lines, regressions = report.diff(
        [{"kind": "summary", **base.get("summary", {})}],
        [{"kind": "summary", **fresh.get("summary", {})}], tol_scale)
    gating = fresh.get("rounds") == base.get("rounds")
    tag = "" if gating else " (round counts differ: informational)"
    for line in lines:
        print(f"       {line}{tag}")
    if gating and regressions:
        _fail(problems, f"summary regression in {', '.join(regressions)}")


def check_gauntlet(fresh, base, problems):
    fs, bs = fresh["schemes"], base["schemes"]
    if set(fs) == set(bs):
        _ok(f"scheme set unchanged ({len(fs)} schemes)")
    else:
        _fail(problems, f"scheme set drifted: fresh-only="
                        f"{sorted(set(fs) - set(bs))} committed-only="
                        f"{sorted(set(bs) - set(fs))}")
    for name in sorted(set(fs) & set(bs)):
        if fs[name]["engine"] != bs[name]["engine"]:
            _fail(problems, f"{name}: engine {bs[name]['engine']} -> "
                            f"{fs[name]['engine']}")
    ratio = fs["scaffold"]["uplink_mb"] / max(fs["syn"]["uplink_mb"], 1e-9)
    if abs(ratio - 2.0) <= 0.3:
        _ok(f"scaffold/syn uplink ratio = {ratio:.3f} (2x within 15%)")
    else:
        _fail(problems, f"scaffold/syn uplink ratio = {ratio:.3f}, "
                        "expected 2x within 15%")
    for name, rec in sorted(fs.items()):
        if rec["uplink_mb"] <= 0:
            _fail(problems, f"{name}: uplink_mb == {rec['uplink_mb']}")
        if rec.get("downlink_mb", 0) <= 0:
            _fail(problems, f"{name}: downlink_mb == "
                            f"{rec.get('downlink_mb')}")
    _ok("every scheme moved bytes both directions")


def check_contracts(fresh, base, problems):
    for side, d in (("fresh", fresh), ("committed", base)):
        off = d["results"]["off"]["counters"]
        if all(v == 0 for v in off.values()):
            _ok(f"{side}: every off-mode counter zero")
        else:
            _fail(problems, f"{side}: off-mode counters nonzero: "
                            f"{ {k: v for k, v in off.items() if v} }")
    fresh_on = fresh["results"]["on"]["counters"]
    for k, v in base["results"]["on"]["counters"].items():
        if v > 0 and fresh_on.get(k, 0) == 0:
            _fail(problems, f"on-mode check family {k} collapsed to zero "
                            f"(committed ran {v})")
    _ok("on-mode check families still exercised")


def check_serve_traffic(fresh, base, problems):
    for side, d in (("fresh", fresh), ("committed", base)):
        progs = d.get("programs", {})
        if progs == {"prefill": 1, "decode": 1}:
            _ok(f"{side}: one compiled program per serving seam "
                "across all swaps")
        else:
            _fail(problems, f"{side}: hot swap recompiled the serving "
                            f"path: programs={progs}")
    res = fresh["results"]
    if res.get("swaps", 0) >= 1:
        _ok(f"swaps = {res['swaps']} (>= 1)")
    else:
        _fail(problems, "no hot swap observed (swaps == "
                        f"{res.get('swaps')})")
    # polling decides only the LATEST step, so snapshots superseded
    # between polls are legitimately never decided — gate the ordering
    # invariants, not a decided-per-publish count
    decided = res.get("promotions", 0) + res.get("rejections", 0)
    if 1 <= res.get("swaps", 0) <= decided:
        _ok(f"swaps ({res['swaps']}) <= promotion decisions ({decided})")
    else:
        _fail(problems, f"swap/decision ordering broken: swaps="
                        f"{res.get('swaps')} decided={decided}")
    p99 = res.get("p99_ms")
    if p99 is not None and p99 > 0:
        _ok(f"p99 latency recorded ({p99:.1f} ms)")
    else:
        _fail(problems, f"p99 latency missing/invalid: {p99}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", default=None,
                    help=f"bench JSONs to gate (default: {', '.join(FILES)})")
    ap.add_argument("--ref", default="HEAD",
                    help="git ref holding the committed baselines")
    ap.add_argument("--fresh-dir", default=".",
                    help="directory holding the freshly regenerated JSONs")
    ap.add_argument("--tol-scale", type=float, default=1.0,
                    help="scale every repro.obs diff tolerance")
    ap.add_argument("--max-overhead", type=float, default=0.5,
                    help="absolute ceiling on observability overhead_frac")
    args = ap.parse_args(argv)
    problems: list = []
    checked = 0
    for name in args.files or FILES:
        print(f"## {name}")
        fresh_path = os.path.join(args.fresh_dir, os.path.basename(name))
        if not os.path.exists(fresh_path):
            print("  skip: no fresh run (not regenerated in this job)")
            continue
        base = committed_json(os.path.basename(name), args.ref)
        if base is None:
            print(f"  skip: no committed baseline at {args.ref}")
            continue
        with open(fresh_path) as f:
            fresh = json.load(f)
        checked += 1
        if "observability" in name:
            check_observability(fresh, base, problems, args.tol_scale,
                                args.max_overhead)
        elif "gauntlet" in name:
            check_gauntlet(fresh, base, problems)
        elif "contracts" in name:
            check_contracts(fresh, base, problems)
        elif "serve_traffic" in name:
            check_serve_traffic(fresh, base, problems)
        else:
            print("  skip: no checks registered for this file")
    if problems:
        print(f"\n{len(problems)} regression(s):")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"\n{checked} file(s) gated, no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
