"""Subprocess worker for the sharded_population benchmark.

One invocation = one (host-device count, population size) cell: jax locks
its device count at first init, so the device sweep in benchmarks/run.py
spawns this worker with REPRO_HOST_DEVICES set per cell (the same forced
host-device pattern tests/test_dryrun_small.py validates).

  REPRO_HOST_DEVICES=16 python -m benchmarks.sharded_worker \
      --population 1024 --participation 32 --rounds 10
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_HOST_DEVICES", "1"))

import argparse
import json
import time

import jax

from repro.configs import CNNS, HeliosConfig, reduced
from repro.data.federated import partition_iid_lazy
from repro.data.synthetic import class_gaussian_images
from repro.federated import ShardedFLRun, make_fleet, setup_clients


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lenet")
    ap.add_argument("--population", type=int, default=1024)
    ap.add_argument("--participation", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--sampler", default="uniform")
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch-size", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(CNNS[args.model])
    imgs, labels = class_gaussian_images(
        8192, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0)
    ti, tl = class_gaussian_images(
        256, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=99)
    n = args.population
    parts = partition_iid_lazy(len(labels), n, seed=0)
    hcfg = HeliosConfig()
    t0 = time.perf_counter()
    clients = setup_clients(make_fleet(n - n // 2, n // 2), parts, hcfg)
    run = ShardedFLRun(cfg, hcfg, "helios", clients,
                       {"images": imgs, "labels": labels},
                       {"images": ti, "labels": tl},
                       local_steps=args.local_steps,
                       batch_size=args.batch_size, lr=0.05, seed=0,
                       participation=args.participation,
                       sampler=args.sampler)
    setup_s = time.perf_counter() - t0

    run.run_sync(1, eval_every=0)                 # compile warmup
    jax.block_until_ready(run.global_params)
    t0 = time.perf_counter()
    run.run_sync(args.rounds, eval_every=0)
    jax.block_until_ready(run.global_params)
    dt = time.perf_counter() - t0

    rec = {
        "model": args.model, "population": n,
        "participation": args.participation, "sampler": args.sampler,
        "devices": len(jax.devices()),
        "mesh_shards": int(run._mesh.devices.size),
        "kpad": run._kpad, "rounds": args.rounds,
        "rounds_per_sec": args.rounds / dt,
        "sec_per_round": dt / args.rounds,
        "setup_s": setup_s,
        # 1 == no recompile across sampled cohorts after warmup
        "compiled_programs": run._round_fn._cache_size(),
        "distinct_cohorts": len({tuple(c) for c in run.cohort_log}),
    }
    print("SHARDED " + json.dumps(rec), flush=True)


if __name__ == "__main__":
    main()
