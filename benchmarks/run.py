"""Benchmark harness — one function per paper table/figure.

Output convention: ``name,us_per_call,derived`` CSV rows.
  * FL tables: name = table/scheme/setting, us_per_call = simulated wall
    time per aggregation cycle (in microtime units x1e6), derived = accuracy
    or speedup.
  * kernel benches: us_per_call = wall microseconds per call (CPU interpret
    for Pallas), derived = allclose max-error vs the oracle.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only t1,t2]
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts as CT
from repro.configs import CNNS, HeliosConfig, reduced
from repro.core import theory
from repro.data.federated import (partition_iid, partition_noniid,
                                  partition_noniid_lazy)
from repro.data.synthetic import class_gaussian_images
from repro.federated import (SCHEMES, AsyncFLRun, BatchedFLRun, FLRun,
                             make_fleet, make_scheme, setup_clients)

ROWS = []


def emit(name: str, us_per_call: float, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


#: task difficulty calibrated so convergence takes 10+ rounds (the paper's
#: CIFAR regime) — full LeNet; reduced AlexNet/ResNet for CPU cost.
_NOISE = {"lenet": 6.0, "alexnet": 3.0, "resnet18": 3.0}


def _world(model: str, n_clients: int, noniid: bool = True, seed: int = 0):
    cfg = CNNS[model] if model == "lenet" else reduced(CNNS[model])
    noise = _NOISE.get(model, 4.0)
    imgs, labels = class_gaussian_images(
        2000, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=seed,
        noise=noise)
    ti, tl = class_gaussian_images(
        512, cfg.image_size, cfg.in_channels, cfg.num_classes,
        seed=seed + 99, noise=noise)
    if noniid:
        parts = partition_noniid(labels, n_clients, shards_per_client=4,
                                 seed=seed)
    else:
        parts = partition_iid(len(labels), n_clients, seed=seed)
    return cfg, imgs, labels, ti, tl, parts


def _run_scheme(world, scheme, n_capable, n_straggler, rounds, lr=0.02,
                hcfg=None, seed=0):
    cfg, imgs, labels, ti, tl, parts = world
    hcfg = hcfg or HeliosConfig()
    clients = setup_clients(make_fleet(n_capable, n_straggler), parts, hcfg)
    run = FLRun(cfg, hcfg, scheme, clients,
                {"images": imgs, "labels": labels},
                {"images": ti, "labels": tl},
                local_steps=2, lr=lr, seed=seed)
    # the Scheme object is the one authority on sync-vs-event execution
    # (the old inline name list here silently ran new sync schemes async)
    if make_scheme(scheme).async_native:
        hist = run.run_async(rounds)
    else:
        hist = run.run_sync(rounds)
    return hist


def _acc_at_time(hist, t):
    best = 0.0
    for h in hist:
        if h["time"] <= t:
            best = max(best, h["acc"])
    return best


def _time_to_acc(hist, target):
    for h in hist:
        if h["acc"] >= target:
            return h["time"]
    return float("inf")


# ---------------------------------------------------------------------------
# Fig. 5 / §VII.B: convergence accuracy, 4- and 6-device settings
# ---------------------------------------------------------------------------


def table_convergence(models=("lenet", "alexnet", "resnet18"), rounds=14):
    for model in models:
        for (nc, ns) in ((2, 2), (3, 3)):
            world = _world(model, nc + ns)
            for scheme in ("syn", "asyn", "random", "afo", "helios"):
                hist = _run_scheme(world, scheme, nc, ns, rounds)
                cyc_t = hist[-1]["time"] / max(hist[-1]["cycle"], 1)
                emit(f"fig5/{model}/{nc + ns}dev/{scheme}", cyc_t * 1e6,
                     f"acc={hist[-1]['acc']:.3f}")


# ---------------------------------------------------------------------------
# §VII.B: speedup vs Syn FL (paper: up to 2.5x)
# ---------------------------------------------------------------------------


def table_speedup(model="lenet", rounds=16, target=0.4):
    for (nc, ns) in ((2, 2), (3, 3)):
        world = _world(model, nc + ns)
        base = _run_scheme(world, "syn", nc, ns, rounds)
        t_syn = _time_to_acc(base, target)
        for scheme in ("helios", "random", "afo"):
            hist = _run_scheme(world, scheme, nc, ns, rounds * 3
                               if scheme == "helios" else rounds)
            t = _time_to_acc(hist, target)
            sp = t_syn / t if np.isfinite(t) else 0.0
            emit(f"speedup/{model}/{nc + ns}dev/{scheme}",
                 (t if np.isfinite(t) else -1) * 1e6,
                 f"speedup_vs_syn={sp:.2f}x")


# ---------------------------------------------------------------------------
# Fig. 6 / §VII.C: aggregation optimization (Helios vs S.T. Only)
# ---------------------------------------------------------------------------


def table_aggregation_opt(model="lenet", rounds=10):
    for ns in (1, 2, 3, 4):
        world = _world(model, 2 + ns)
        h_st = _run_scheme(world, "st_only", 2, ns, rounds)
        h_he = _run_scheme(world, "helios", 2, ns, rounds)
        gain = h_he[-1]["acc"] - h_st[-1]["acc"]
        emit(f"fig6/{model}/{ns}stragglers/helios_vs_st_only",
             h_he[-1]["time"] / rounds * 1e6,
             f"acc_st={h_st[-1]['acc']:.3f};acc_helios={h_he[-1]['acc']:.3f};"
             f"gain={gain:+.3f}")


# ---------------------------------------------------------------------------
# Fig. 7 / §VII.D: Non-IID evaluation
# ---------------------------------------------------------------------------


def table_noniid(model="lenet", rounds=12):
    for (nc, ns) in ((2, 2), (3, 3)):
        for noniid in (False, True):
            world = _world(model, nc + ns, noniid=noniid)
            for scheme in ("syn", "asyn", "helios"):
                hist = _run_scheme(world, scheme, nc, ns, rounds)
                tag = "noniid" if noniid else "iid"
                emit(f"fig7/{model}/{nc + ns}dev/{tag}/{scheme}",
                     hist[-1]["time"] / max(hist[-1]["cycle"], 1) * 1e6,
                     f"acc={hist[-1]['acc']:.3f}")


# ---------------------------------------------------------------------------
# ablation: P_s (top-contribution fraction, Section VI.A: "0.05 to 0.1")
# ---------------------------------------------------------------------------


def table_ps_ablation(model="lenet", rounds=10):
    """P_s=0 is pure-random rotation (≈ Caldas); large P_s freezes the
    rotation (top units monopolize).  The paper picks 0.05-0.1."""
    world = _world(model, 4)
    for p_s in (0.0, 0.05, 0.1, 0.3):
        hcfg = HeliosConfig(p_s=p_s)
        hist = _run_scheme(world, "helios", 2, 2, rounds, hcfg=hcfg)
        emit(f"ablation/p_s={p_s}", hist[-1]["time"] / rounds * 1e6,
             f"acc={hist[-1]['acc']:.3f}")


# ---------------------------------------------------------------------------
# scheme gauntlet: every registered scheme under ONE heterogeneous world
# ---------------------------------------------------------------------------


def _prop2_report(straggler):
    """Prop. 2 numbers for one straggler's CURRENT contribution scores:
    the Wangni sampling distribution at its adapted volume, the Eq. 6
    variance inflation that distribution pays, and the Eq. 9 expected-
    sparsity bound — the theory column of the gauntlet (what soft
    training costs in gradient variance at the volume it settled on)."""
    g = jnp.concatenate(
        [jnp.asarray(v, jnp.float32).ravel()
         for v in jax.tree.leaves(straggler.helios_state["scores"])])
    n = int(g.shape[0])
    v = max(1, int(float(straggler.volume) * n))
    p = theory.wangni_probabilities(g, v)
    lhs, rhs = theory.check_convergence_condition(g, v, rho=0.5)
    return {"score_units": n, "volume": float(straggler.volume),
            "top_v": v,
            "variance_inflation": float(theory.variance_inflation(g, p)),
            "expected_sparsity": float(lhs), "eq9_bound": float(rhs),
            "eq9_holds": bool(float(lhs) <= float(rhs) + 1e-6)}


def table_scheme_gauntlet(model="lenet", rounds=12, nc=4, ns=4, seed=0,
                          out_path="BENCH_scheme_gauntlet.json"):
    """Every scheme in federated.schemes.SCHEMES — paper ablations AND the
    published straggler baselines (SCAFFOLD / FLuID / delayed-gradient) —
    under the IDENTICAL heterogeneous world: same non-IID partition, same
    half-straggler fleet, same seed.  Per scheme: the accuracy trajectory
    against SIMULATED wall-clock (each scheme's own round clock — syn
    waits for stragglers, delayed does not), total uplink bytes
    (scaffold's control variates ride dense at 2x), and for the
    soft-training schemes the Prop. 2 variance-inflation report at the
    straggler volumes the run settled on.  The JSON is the
    accuracy-vs-time-vs-uplink frontier the README table reads from.

    Engine per the scheme's own flag: async_native schemes run the
    bucketed event engine, everything else the batched sync engine.
    """
    import json

    cfg, imgs, labels, ti, tl, parts = _world(model, nc + ns, noniid=True,
                                              seed=seed)
    train = {"images": imgs, "labels": labels}
    test = {"images": ti, "labels": tl}
    results = {}
    for scheme in SCHEMES:
        sch = make_scheme(scheme)
        hcfg = HeliosConfig()
        clients = setup_clients(make_fleet(nc, ns), parts, hcfg)
        cls = AsyncFLRun if sch.async_native else BatchedFLRun
        run = cls(cfg, hcfg, scheme, clients, train, test,
                  local_steps=2, lr=0.02, seed=seed)
        if sch.async_native:
            # same capable-cycle budget convention as _run_scheme
            hist = run.run_async(rounds)
        else:
            hist = run.run_sync(rounds)
        rec = {
            "engine": cls.__name__,
            "final_acc": hist[-1]["acc"],
            "sim_time": hist[-1]["time"],
            "uplink_mb": run.uplink_bytes() / 1e6,
            "downlink_mb": run.downlink_bytes() / 1e6,
            "trajectory": [{"time": round(h["time"], 4),
                            "acc": round(h["acc"], 4),
                            "downlink_mb": round(h.get("downlink_mb", 0.0),
                                                 4)} for h in hist],
        }
        if sch.soft_training:
            strag = next(c for c in run.clients if c.is_straggler)
            rec["prop2"] = _prop2_report(strag)
        results[scheme] = rec
        extra = ""
        if "prop2" in rec:
            extra = (f";var_inflation={rec['prop2']['variance_inflation']:.3f}"
                     f";eq9={'ok' if rec['prop2']['eq9_holds'] else 'FAIL'}")
        emit(f"scheme_gauntlet/{model}/{scheme}",
             rec["sim_time"] / max(hist[-1]["cycle"], 1) * 1e6,
             f"acc={rec['final_acc']:.3f};simtime={rec['sim_time']:.2f};"
             f"uplink_mb={rec['uplink_mb']:.2f};"
             f"downlink_mb={rec['downlink_mb']:.2f}" + extra)
    with open(out_path, "w") as f:
        json.dump({"model": model, "rounds": rounds,
                   "fleet": {"capable": nc, "stragglers": ns},
                   "partition": "noniid", "seed": seed,
                   "local_steps": 2, "lr": 0.02,
                   "schemes": results,
                   "note": ("one world, every scheme: accuracy is at equal "
                            "ROUNDS; compare at equal sim_time for the "
                            "wall-clock frontier (each scheme's round "
                            "clock differs by design) and against "
                            "uplink_mb for the communication frontier; "
                            "prop2 rows price soft-training's gradient "
                            "variance (Eq. 6/9) at the settled volumes")},
                  f, indent=2)
    print(f"wrote {out_path}")


# ---------------------------------------------------------------------------
# batched round engine: rounds/sec, sequential vs vmapped cohorts
# ---------------------------------------------------------------------------


def _engine_throughput(tag, cfg, hcfg, train_data, test_data, parts_for,
                       counts, rounds, **run_kw):
    """Sequential-vs-batched rounds/sec over population sizes ``counts``.

    Shared by the CNN and LM throughput tables: warmup round (compile),
    timed eval-free window, per-count speedup rows via ``emit``.  Half the
    fleet are stragglers; ``parts_for(n)`` supplies the data partition.
    """
    results = []
    for n in counts:
        parts = parts_for(n)
        row = {"clients": n}
        for name, cls in (("sequential", FLRun), ("batched", BatchedFLRun)):
            clients = setup_clients(make_fleet(n - n // 2, n // 2), parts,
                                    hcfg)
            run = cls(cfg, hcfg, "helios", clients, train_data, test_data,
                      seed=0, **run_kw)
            run.run_sync(1, eval_every=0)                 # compile warmup
            jax.block_until_ready(run.global_params)
            t0 = time.perf_counter()
            run.run_sync(rounds, eval_every=0)            # no eval in window
            jax.block_until_ready(run.global_params)
            dt = time.perf_counter() - t0
            row[name] = {"rounds_per_sec": rounds / dt,
                         "sec_per_round": dt / rounds}
        row["speedup"] = (row["batched"]["rounds_per_sec"]
                          / row["sequential"]["rounds_per_sec"])
        emit(f"{tag}/{n}clients/sequential",
             row["sequential"]["sec_per_round"] * 1e6,
             f"rounds_per_sec={row['sequential']['rounds_per_sec']:.3f}")
        emit(f"{tag}/{n}clients/batched",
             row["batched"]["sec_per_round"] * 1e6,
             f"rounds_per_sec={row['batched']['rounds_per_sec']:.3f};"
             f"speedup_vs_sequential={row['speedup']:.2f}x")
        results.append(row)
    return results


def table_batched_rounds(model="lenet", counts=(16, 64, 256), rounds=3,
                         out_path="BENCH_batched_rounds.json"):
    """Round throughput at simulated-population scale.

    Cross-device regime: 1 local step, batch 16 per client, half the fleet
    stragglers.  The sequential engine pays O(clients) host dispatch + eager
    Helios state updates per round; the batched engine runs each round as
    one jitted vmapped program.  Results land in ``BENCH_batched_rounds.json``.
    """
    import json

    cfg = reduced(CNNS[model])
    noise = _NOISE.get(model, 4.0)
    imgs, labels = class_gaussian_images(
        2000, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0,
        noise=noise)
    ti, tl = class_gaussian_images(
        256, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=99,
        noise=noise)
    run_kw = dict(local_steps=1, batch_size=16, lr=0.05)
    results = _engine_throughput(
        f"batched_rounds/{model}", cfg, HeliosConfig(),
        {"images": imgs, "labels": labels}, {"images": ti, "labels": tl},
        lambda n: partition_iid(len(labels), n, seed=0), counts, rounds,
        **run_kw)
    with open(out_path, "w") as f:
        json.dump({"model": model, "rounds": rounds, "scheme": "helios",
                   **run_kw, "results": results,
                   "contract_counters": dict(CT.counters)}, f, indent=2)
    print(f"wrote {out_path}")


# ---------------------------------------------------------------------------
# federated LM via the family-adapter seam: rounds/sec + CE trajectory
# ---------------------------------------------------------------------------


def table_federated_lm(arch="deepseek-7b", counts=(4, 8), rounds=3,
                       ce_rounds=4, out_path="BENCH_federated_lm.json"):
    """Federated LM round throughput, sequential vs batched engines.

    A reduced dense transformer trains on Non-IID Markov-topic token
    streams (partition_by_topic) with half the fleet stragglers; the CE
    trajectory (helios scheme, eval on the full test set per round) shows
    the LM actually learns through the soft-training path.  Results land in
    ``BENCH_federated_lm.json``.
    """
    import json

    from repro.configs import ARCHS
    from repro.data.federated import partition_by_topic
    from repro.data.synthetic import markov_topic_tokens

    cfg = reduced(ARCHS[arch])
    data_vocab = min(64, cfg.vocab_size)
    tokens, topics = markov_topic_tokens(768, 48, data_vocab,
                                         n_topics=8, seed=0)
    test_tokens, _ = markov_topic_tokens(128, 48, data_vocab,
                                         n_topics=8, seed=99)
    hcfg = HeliosConfig()
    train, test = {"tokens": tokens}, {"tokens": test_tokens}

    def parts_for(n):
        return partition_by_topic(topics, n, topics_per_client=2)

    tp_kw = dict(local_steps=1, batch_size=8, lr=0.1)
    results = _engine_throughput(f"federated_lm/{arch}", cfg, hcfg, train,
                                 test, parts_for, counts, rounds,
                                 eval_batch=64, **tp_kw)

    # CE trajectory: fresh batched run with full-test-set eval every round
    # (hotter hyperparameters than the throughput window — recorded as such)
    n = counts[0]
    ce_kw = dict(local_steps=4, batch_size=8, lr=0.5)
    clients = setup_clients(make_fleet(n - n // 2, n // 2), parts_for(n),
                            hcfg)
    run = BatchedFLRun(cfg, hcfg, "helios", clients, train, test, seed=0,
                       eval_batch=64, **ce_kw)
    hist = run.run_sync(ce_rounds)
    traj = [round(h["ce"], 4) for h in hist]
    emit(f"federated_lm/{arch}/{n}clients/ce_trajectory",
         hist[-1]["time"] / max(hist[-1]["cycle"], 1) * 1e6,
         "ce=" + "->".join(f"{c:.2f}" for c in traj))
    with open(out_path, "w") as f:
        json.dump({"arch": arch, "family": cfg.family, "scheme": "helios",
                   "data_vocab": data_vocab,
                   "uniform_ce": float(np.log(cfg.vocab_size)),
                   "throughput": {"rounds": rounds, **tp_kw,
                                  "results": results},
                   "ce": {"rounds": ce_rounds, "clients": n, **ce_kw,
                          "trajectory": traj}}, f, indent=2)
    print(f"wrote {out_path}")


# ---------------------------------------------------------------------------
# population-scale rounds: ShardedFLRun, partial participation, device sweep
# ---------------------------------------------------------------------------


def table_sharded_population(devices=(1, 2, 4, 8, 16),
                             populations=(256, 1024, 4096),
                             participation=32, rounds=15,
                             out_path="BENCH_sharded_population.json"):
    """Rounds/sec for the client-sharded population engine.

    Two axes, K=32 sampled per round throughout:
      * host devices 1 -> 16 at N=1024 (the shard_map scaling axis);
      * population N in {256, 1024, 4096} at the max device count (the
        persistent-population axis — rounds/sec must be ~N-independent,
        because only K rows ever move and data indexing is lazy).

    jax pins its device count at first init, so every cell runs in a
    SUBPROCESS with REPRO_HOST_DEVICES set (benchmarks/sharded_worker.py,
    the same forced-host-device pattern the dry-run tests validate).  Each
    worker asserts shape-stable compilation: exactly ONE compiled round
    program across all sampled cohorts after warmup.

    Device-sweep caveat recorded in the JSON: wall-clock scaling is bounded
    by PHYSICAL cores (a 1-device XLA CPU baseline already multi-threads),
    so on small containers the sweep validates overhead, not speedup.
    """
    import json
    import os as _os
    import subprocess
    import sys

    repo = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))

    def cell(n, dev):
        env = dict(_os.environ, REPRO_HOST_DEVICES=str(dev),
                   PYTHONPATH=_os.path.join(repo, "src"))
        cmd = [sys.executable, "-m", "benchmarks.sharded_worker",
               "--population", str(n), "--participation",
               str(participation), "--rounds", str(rounds)]
        r = subprocess.run(cmd, env=env, cwd=repo, capture_output=True,
                           text=True, timeout=1800)
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("SHARDED ")][-1]
        rec = json.loads(line[len("SHARDED "):])
        assert rec["compiled_programs"] == 1, rec   # no recompile per draw
        emit(f"sharded_population/N={n}/dev={dev}",
             rec["sec_per_round"] * 1e6,
             f"rounds_per_sec={rec['rounds_per_sec']:.2f};"
             f"kpad={rec['kpad']};programs={rec['compiled_programs']}")
        return rec

    mid = populations[len(populations) // 2]
    sweep_dev = [cell(mid, d) for d in devices]
    sweep_pop = [cell(n, devices[-1]) for n in populations if n != mid]
    base = sweep_dev[0]["rounds_per_sec"]
    best = max(r["rounds_per_sec"] for r in sweep_dev)
    emit(f"sharded_population/N={mid}/device_sweep", 0.0,
         f"best_speedup_vs_1dev={best / base:.2f}x;"
         f"cpu_cores={_os.cpu_count()}")
    with open(out_path, "w") as f:
        json.dump({
            "participation": participation, "rounds": rounds,
            "scheme": "helios", "sampler": "uniform",
            "host_cpu_count": _os.cpu_count(),
            "device_sweep": sweep_dev,
            "population_sweep": sweep_pop,
            "best_speedup_vs_1dev": best / base,
            "note": ("device sweep is bounded by physical cores: the "
                     "1-device XLA CPU baseline already multi-threads "
                     "(cpu/wall ~1.4 on a 2-core host), so >=2x needs "
                     "cores >= shards; cohort-shape-stable padding holds "
                     "(compiled_programs == 1 in every cell)"),
        }, f, indent=2)
    print(f"wrote {out_path}")


# ---------------------------------------------------------------------------
# async events: sequential event loop vs bucketed AsyncFLRun, events/sec
# ---------------------------------------------------------------------------


def table_async_events(model="lenet", counts=(64, 256, 1024),
                       capable_per_client=1.0,
                       out_path="BENCH_async_events.json"):
    """Events/sec for the async schemes (afo), half-straggler fleets.

    The sequential reference dispatches one jitted client cycle + a
    host-dict snapshot per completion event — O(events) host overhead.
    The bucketed engine executes each equal-time tie-group as ONE vmapped
    program reading/writing a device snapshot ring, so host dispatch is
    O(buckets).  Both engines process the IDENTICAL event set for a fixed
    seed (tests/test_async_engine.py pins the trajectories), which makes
    events/sec an apples-to-apples execution-layer number.  Data partitions
    are lazy non-IID (partition_noniid_lazy): no N per-client index arrays.
    """
    import json

    cfg = reduced(CNNS[model])
    noise = _NOISE.get(model, 4.0)
    imgs, labels = class_gaussian_images(
        4096, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0,
        noise=noise)
    ti, tl = class_gaussian_images(
        128, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=99,
        noise=noise)
    train, test = {"images": imgs, "labels": labels}, \
        {"images": ti, "labels": tl}
    hcfg = HeliosConfig()
    run_kw = dict(local_steps=1, batch_size=16, lr=0.05, seed=0)
    results = []
    for n in counts:
        parts = partition_noniid_lazy(labels, n, shards_per_client=4,
                                      seed=0)
        capable = max(16, int(n * capable_per_client))
        row = {"clients": n, "capable_cycles": capable}
        for name, cls in (("sequential", FLRun), ("bucketed", AsyncFLRun)):
            clients = setup_clients(make_fleet(n - n // 2, n // 2), parts,
                                    hcfg)
            run = cls(cfg, hcfg, "afo", clients, train, test, **run_kw)
            # warmup over the SAME capable budget: the event schedule is
            # deterministic from t=0, so this visits exactly the bucket
            # shapes the timed window will, compiling all of them up front
            run.run_async(capable, eval_every=0)
            jax.block_until_ready(run.global_params)
            t0 = time.perf_counter()
            run.run_async(capable, eval_every=0)
            jax.block_until_ready(run.global_params)
            dt = time.perf_counter() - t0
            row[name] = {"events": run.events_processed,
                         "seconds": dt,
                         "events_per_sec": run.events_processed / dt}
            if name == "bucketed":
                progs = run.bucket_programs()
                # shape-stable: one compile per padded bucket size
                assert all(v == 1 for v in progs.values()), progs
                row[name]["bucket_programs"] = {str(k): v
                                                for k, v in progs.items()}
                row[name]["mean_bucket"] = float(np.mean(run.bucket_sizes))
        row["speedup"] = (row["bucketed"]["events_per_sec"]
                          / row["sequential"]["events_per_sec"])
        emit(f"async_events/{model}/{n}clients/sequential",
             1e6 / row["sequential"]["events_per_sec"],
             f"events_per_sec={row['sequential']['events_per_sec']:.1f}")
        emit(f"async_events/{model}/{n}clients/bucketed",
             1e6 / row["bucketed"]["events_per_sec"],
             f"events_per_sec={row['bucketed']['events_per_sec']:.1f};"
             f"speedup_vs_sequential={row['speedup']:.2f}x;"
             f"mean_bucket={row['bucketed']['mean_bucket']:.1f}")
        results.append(row)
    with open(out_path, "w") as f:
        json.dump({"model": model, "scheme": "afo",
                   "partition": "noniid_lazy", **run_kw,
                   "results": results,
                   "contract_counters": dict(CT.counters)}, f, indent=2)
    print(f"wrote {out_path}")


# ---------------------------------------------------------------------------
# runtime contracts: guard overhead, off vs on
# ---------------------------------------------------------------------------


def table_contracts_overhead(model="lenet", n_clients=8, rounds=6,
                             out_path="BENCH_contracts.json"):
    """repro.analysis.contracts cost on the batched engine, off vs on.

    Same seed/fleet/trajectory both ways; ``off`` is the default CI/bench
    mode and must be genuinely free — no guard installed, every counter
    still zero after the run (asserted and recorded).  ``on`` pays the
    transfer-guard sections plus the per-run finite/mask/compile checks;
    the JSON records the counter census so regressions in check volume
    are visible, not just wall time.
    """
    import json

    cfg = reduced(CNNS[model])
    noise = _NOISE.get(model, 4.0)
    imgs, labels = class_gaussian_images(
        1024, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0,
        noise=noise)
    ti, tl = class_gaussian_images(
        128, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=99,
        noise=noise)
    parts = partition_iid(len(labels), n_clients, seed=0)
    run_kw = dict(local_steps=1, batch_size=16, lr=0.05, seed=0)
    results = {}
    for mode in ("off", "on"):
        CT.reset_counters()
        clients = setup_clients(make_fleet(n_clients - n_clients // 2,
                                           n_clients // 2), parts,
                                HeliosConfig())
        run = BatchedFLRun(cfg, HeliosConfig(), "helios", clients,
                           {"images": imgs, "labels": labels},
                           {"images": ti, "labels": tl}, **run_kw)
        with CT.override(mode == "on"):
            run.run_sync(1, eval_every=0)                 # compile warmup
            jax.block_until_ready(run.global_params)
            t0 = time.perf_counter()
            run.run_sync(rounds, eval_every=0)
            jax.block_until_ready(run.global_params)
            dt = time.perf_counter() - t0
        results[mode] = {"sec_per_round": dt / rounds,
                         "rounds_per_sec": rounds / dt,
                         "counters": dict(CT.counters)}
    off, on = results["off"], results["on"]
    assert all(v == 0 for v in off["counters"].values()), off["counters"]
    overhead = on["sec_per_round"] / off["sec_per_round"] - 1.0
    emit(f"contracts/{model}/{n_clients}clients/off",
         off["sec_per_round"] * 1e6,
         f"rounds_per_sec={off['rounds_per_sec']:.3f}")
    emit(f"contracts/{model}/{n_clients}clients/on",
         on["sec_per_round"] * 1e6,
         f"rounds_per_sec={on['rounds_per_sec']:.3f};"
         f"overhead={overhead * 100:+.1f}%;"
         f"checks={sum(on['counters'].values())}")
    with open(out_path, "w") as f:
        json.dump({"model": model, "clients": n_clients, "rounds": rounds,
                   "scheme": "helios", **{k: v for k, v in run_kw.items()
                                          if k != "seed"},
                   "results": results, "overhead_frac": overhead}, f,
                  indent=2)
    print(f"wrote {out_path}")


# ---------------------------------------------------------------------------
# observability: telemetry cost on the batched engine, off vs on
# ---------------------------------------------------------------------------


def table_observability(model="lenet", n_clients=8, rounds=6, reps=3,
                        out_path="BENCH_observability.json",
                        run_dir="obs_run"):
    """repro.obs telemetry cost on the batched engine, off vs on (the
    table_contracts_overhead pattern, applied to the other arming seam).

    Same seed/fleet/trajectory both ways.  ``off`` is the default mode:
    the recorder still does the engine's accounting (counters/accums are
    the bookkeeping itself) but must buffer ZERO events (asserted and
    recorded).  ``on`` pays span/event emission inside the round loop;
    the timed window is eval-free so ``overhead_frac`` prices telemetry
    alone.  The armed run then takes two evaluated rounds (untimed, both
    modes, so trajectories stay comparable) and flushes its run log to
    ``run_dir`` — the input for ``python -m repro.obs report``.  The JSON
    carries the armed run's manifest and a run-log-shaped ``summary``
    block so ``python -m repro.obs diff`` compares this bench file and a
    fresh run log uniformly.
    """
    import json

    from repro.obs import recorder as OBS
    from repro.obs import report as OBR

    cfg = reduced(CNNS[model])
    noise = _NOISE.get(model, 4.0)
    imgs, labels = class_gaussian_images(
        1024, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0,
        noise=noise)
    ti, tl = class_gaussian_images(
        128, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=99,
        noise=noise)
    parts = partition_iid(len(labels), n_clients, seed=0)
    run_kw = dict(local_steps=1, batch_size=16, lr=0.05, seed=0)
    runs, best = {}, {}
    for mode in ("off", "on"):
        clients = setup_clients(make_fleet(n_clients - n_clients // 2,
                                           n_clients // 2), parts,
                                HeliosConfig())
        with OBS.override(mode == "on"):
            # the recorder arms at construction, so the run is built
            # inside the override (exactly how REPRO_OBS=on would see it)
            run = BatchedFLRun(cfg, HeliosConfig(), "helios", clients,
                               {"images": imgs, "labels": labels},
                               {"images": ti, "labels": tl}, **run_kw)
        run.run_sync(1, eval_every=0)                     # compile warmup
        jax.block_until_ready(run.global_params)
        runs[mode], best[mode] = run, float("inf")
    # interleaved min-of-reps laps: the eval-free window is short
    # (~rounds x tens of ms), so back-to-back off-then-on measurement
    # would fold host frequency drift into the overhead number
    for _ in range(reps):
        for mode, run in runs.items():
            t0 = time.perf_counter()
            run.run_sync(rounds, eval_every=0)
            jax.block_until_ready(run.global_params)
            best[mode] = min(best[mode], time.perf_counter() - t0)
    results = {}
    for mode, run in runs.items():
        hist = run.run_sync(2, eval_every=1)              # untimed, w/ eval
        results[mode] = {"sec_per_round": best[mode] / rounds,
                         "rounds_per_sec": rounds / best[mode],
                         "events": len(run.rec.events),
                         "counters": dict(run.rec.counters)}
    assert not runs["off"].rec.armed and not runs["off"].rec.events, \
        "disarmed recorder buffered events"
    armed_run, armed_hist = runs["on"], hist
    off, on = results["off"], results["on"]
    overhead = on["sec_per_round"] / off["sec_per_round"] - 1.0
    emit(f"observability/{model}/{n_clients}clients/off",
         off["sec_per_round"] * 1e6,
         f"rounds_per_sec={off['rounds_per_sec']:.3f};events=0")
    emit(f"observability/{model}/{n_clients}clients/on",
         on["sec_per_round"] * 1e6,
         f"rounds_per_sec={on['rounds_per_sec']:.3f};"
         f"overhead={overhead * 100:+.1f}%;events={on['events']}")
    flushed = armed_run.rec.flush(run_dir)
    print(f"wrote {flushed['events']}")
    summary = OBR.summarize(
        OBR.load_events(os.path.join(run_dir, "events.jsonl")))
    with open(out_path, "w") as f:
        json.dump({"model": model, "clients": n_clients, "rounds": rounds,
                   "scheme": "helios",
                   **{k: v for k, v in run_kw.items() if k != "seed"},
                   "results": results, "overhead_frac": overhead,
                   "final_acc": armed_hist[-1]["acc"],
                   "manifest": dict(armed_run.rec.manifest),
                   "summary": summary}, f, indent=2)
    print(f"wrote {out_path}")


# ---------------------------------------------------------------------------
# serve-while-you-train: Poisson traffic against the live global model
# ---------------------------------------------------------------------------


def table_serve_traffic(arch="deepseek-7b", n_clients=4, rounds=4,
                        rate_hz=20.0, batch=4, prompt_len=16, gen=4,
                        kernels="reference", max_requests=200,
                        out_path="BENCH_serve_traffic.json",
                        run_dir="obs_serve"):
    """The first bench that measures the system as a SERVICE: batched
    generation traffic served against the live global model while a
    `BatchedFLRun` trains concurrently in the same process.

    The training thread publishes atomic snapshots every round
    (``publish_dir``); the serving thread polls them behind the
    eval-gated promotion rule and hot-swaps lock-free (params are a
    traced argument, so ``GenerationServer`` keeps ONE compiled
    prefill + ONE decode program across every swap — asserted).  Load
    is an open-loop Poisson arrival schedule (fixed by seed): latency
    per request is completion minus SCHEDULED arrival, so queueing
    delay under overload is priced in rather than the arrival process
    quietly slowing down, and a decode's intermediate steps stay
    async-dispatched — each request blocks once, on its own response.
    Both planes share one armed recorder, flushed to ``run_dir`` for
    ``python -m repro.obs report``.
    """
    import json
    import tempfile

    from repro import checkpoint as CKPT
    from repro.configs import ARCHS
    from repro.data.federated import partition_by_topic
    from repro.data.synthetic import markov_tokens, markov_topic_tokens
    from repro.launch.serve import (GenerationServer, PoissonTraffic,
                                    ServeLoop, make_ce_eval, serve_batch,
                                    serve_while_training)
    from repro.models import init_params
    from repro.obs import recorder as OBS
    from repro.obs import report as OBR

    cfg = reduced(ARCHS[arch])
    data_vocab = min(64, cfg.vocab_size)
    tokens, topics = markov_topic_tokens(256, 32, data_vocab,
                                         n_topics=8, seed=0)
    test_tokens, _ = markov_topic_tokens(64, 32, data_vocab,
                                         n_topics=8, seed=99)
    parts = partition_by_topic(topics, n_clients, topics_per_client=2)
    hcfg = HeliosConfig()
    clients = setup_clients(make_fleet(n_clients - n_clients // 2,
                                       n_clients // 2), parts, hcfg)
    rec = OBS.Recorder(armed=True)
    pub = tempfile.mkdtemp(prefix="serve_pub_")
    run_kw = dict(local_steps=2, batch_size=8, lr=0.1, seed=0,
                  eval_batch=64)
    run = BatchedFLRun(cfg, hcfg, "helios", clients, {"tokens": tokens},
                       {"tokens": test_tokens}, recorder=rec,
                       publish_dir=pub, publish_every=1, **run_kw)

    srv = GenerationServer(cfg, batch, prompt_len, gen=gen, kernels=kernels)
    held = {"tokens": jnp.asarray(test_tokens[:32])}
    serve = ServeLoop(pub, init_params(jax.random.PRNGKey(0), cfg),
                      request_fn=srv, eval_fn=make_ce_eval(cfg, held),
                      higher_is_better=False, tol=0.05, recorder=rec)
    # round 0 snapshot: traffic has something to serve from request one
    CKPT.save(pub, 0, run.global_params, keep=run.publish_keep,
              metadata={"round": 0, "sim_time": 0.0, "scheme": run.scheme})
    assert serve.poll(), "initial snapshot must promote"
    prompts = markov_tokens(batch, prompt_len, cfg.padded_vocab, seed=7)
    req = serve_batch(cfg, prompts, np.random.default_rng(7))
    serve.handle(req)                      # compile warmup, untimed
    traffic = PoissonTraffic(rate_hz=rate_hz, seed=0)
    stats = serve_while_training(lambda: run.run_sync(rounds),
                                 serve, traffic, lambda i: req,
                                 min_requests=10, max_requests=max_requests)

    assert srv.programs() == {"prefill": 1, "decode": 1}, \
        f"hot swap recompiled the serving path: {srv.programs()}"
    swaps = rec.count("serve_swaps")
    assert swaps >= 1 and rec.count("published_snapshots") == rounds
    lat = sorted(stats["latency_ms"])
    n = len(lat)
    p50, p99 = lat[n // 2], lat[min((99 * n) // 100, n - 1)]
    emit(f"serve_traffic/{arch}/{rate_hz:g}hz/{kernels}",
         stats["wall_s"] / max(stats["requests"], 1) * 1e6,
         f"req_per_sec={stats['requests_per_sec']:.1f};"
         f"p50={p50:.1f}ms;p99={p99:.1f}ms;swaps={swaps}")
    flushed = rec.flush(run_dir)
    print(f"wrote {flushed['events']}")
    summary = OBR.summarize(
        OBR.load_events(os.path.join(run_dir, "events.jsonl")))
    with open(out_path, "w") as f:
        json.dump({"arch": arch, "clients": n_clients, "rounds": rounds,
                   "scheme": "helios", "kernels": kernels,
                   "batch": batch, "prompt_len": prompt_len, "gen": gen,
                   **{k: v for k, v in run_kw.items() if k != "seed"},
                   "results": {
                       "requests": stats["requests"],
                       "wall_s": stats["wall_s"],
                       "requests_per_sec": stats["requests_per_sec"],
                       "offered_rate_hz": stats["offered_rate_hz"],
                       "p50_ms": p50, "p99_ms": p99,
                       "swaps": swaps,
                       "promotions": rec.count("serve_promotions"),
                       "rejections": rec.count("serve_rejections"),
                       "published": rec.count("published_snapshots"),
                       "served_step": serve.served_step,
                       "served_round": serve.served_round},
                   "programs": srv.programs(),
                   "manifest": dict(rec.manifest),
                   "summary": summary}, f, indent=2)
    print(f"wrote {out_path}")


# ---------------------------------------------------------------------------
# kernels: wall time + oracle error (CPU interpret)
# ---------------------------------------------------------------------------


def bench_kernels():
    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.masked_matmul import masked_matmul

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 512))
    w = jax.random.normal(jax.random.fold_in(key, 1), (512, 1024))
    for frac, alive in (("dense", jnp.ones(8, bool)),
                        ("quarter", (jnp.arange(8) < 2))):
        f = lambda: masked_matmul(x, w, alive, interpret=True)
        out = f()
        out.block_until_ready()
        t0 = time.time()
        for _ in range(3):
            f().block_until_ready()
        us = (time.time() - t0) / 3 * 1e6
        err = float(jnp.max(jnp.abs(
            out - ref.masked_matmul_ref(x, w, alive, 128))))
        emit(f"kernel/masked_matmul/{frac}", us, f"max_err={err:.2e}")

    q = jax.random.normal(key, (1, 4, 256, 64))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 4, 256, 64))
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, 4, 256, 64))
    f = lambda: flash_attention(q, k, v, causal=True, interpret=True)
    out = f()
    out.block_until_ready()
    t0 = time.time()
    for _ in range(3):
        f().block_until_ready()
    us = (time.time() - t0) / 3 * 1e6
    err = float(jnp.max(jnp.abs(out - ref.flash_attention_ref(q, k, v))))
    emit("kernel/flash_attention/256", us, f"max_err={err:.2e}")


# ---------------------------------------------------------------------------
# kernel-backed soft-training: tokens/sec vs volume fraction P
# ---------------------------------------------------------------------------


def table_kernel_softtrain(fracs=(0.25, 0.5, 0.75, 1.0), steps=4,
                           out_path="BENCH_kernel_softtrain.json"):
    """Soft-training step throughput, reference (plain jnp masked ops) vs
    pallas (block-sparse masked-matmul pair + flash attention), as the
    volume fraction P sweeps the Helios straggler range.

    One jitted train step per substrate serves EVERY P (masks are traced
    0/1 inputs, block-aligned at mask_block=128) — asserted via the jit
    cache size, so the adaptive volume controller never pays a recompile.
    On this CPU container the pallas path runs in interpret mode (the
    kernel body as traced JAX ops): the numbers validate dispatch overhead
    and P-scaling plumbing, NOT kernel wall-clock — the dead-block skip
    turns into real speedup on TPU hosts where the kernels compile natively.
    """
    import json

    from repro.configs.base import ModelConfig
    from repro.kernels.ops import block_align_mask
    from repro.models import build, default_runtime, init_params

    cfg = ModelConfig(name="bench-dense", family="dense", num_layers=2,
                      d_model=128, num_heads=4, num_kv_heads=4, d_ff=512,
                      vocab_size=256, head_dim=32)
    api = build(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 8, 128
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S),
                                          0, 64)}
    schema = api.mask_schema                   # {"heads": (L,H), "mlp": (L,ff)}

    def masks_at(frac):
        out = {}
        for key, (L, n) in schema.items():
            if key == "mlp":
                m = (jnp.arange(n) < max(1, int(frac * n))).astype(jnp.float32)
                m = block_align_mask(m, 128)
                out[key] = jnp.broadcast_to(m, (L, n))
            else:
                out[key] = jnp.ones((L, n), jnp.float32)
        return out

    results = {f: {} for f in fracs}
    compiled = {}
    for impl in ("reference", "pallas"):
        rt = default_runtime(cfg)
        rt["kernels"] = impl
        rt["mask_block"] = 128
        # the python body runs once per TRACE, so this counts compiles
        # without reaching into jit internals
        traces = {"n": 0}

        @jax.jit
        def step(p, masks, rt=rt, traces=traces):
            traces["n"] += 1
            loss, g = jax.value_and_grad(
                lambda pp: api.loss_fn(pp, batch, cfg, rt, masks))(p)
            return jax.tree.map(lambda a, b: a - 0.01 * b, p, g), loss

        for frac in fracs:
            masks = masks_at(frac)
            p = params
            p, _ = step(p, masks)              # warmup (first P compiles)
            jax.block_until_ready(jax.tree.leaves(p)[0])
            t0 = time.perf_counter()
            for _ in range(steps):
                p, loss = step(p, masks)
            jax.block_until_ready(jax.tree.leaves(p)[0])
            dt = time.perf_counter() - t0
            tps = B * S * steps / dt
            results[frac][impl] = {"tokens_per_sec": tps,
                                   "sec_per_step": dt / steps,
                                   "loss": float(loss)}
        # ONE program per substrate across the whole P sweep: volume changes
        # are traced mask values, never new shapes
        compiled[impl] = traces["n"]
        assert compiled[impl] == 1, (impl, compiled[impl])

    rows = []
    for frac in fracs:
        r = results[frac]
        ratio = (r["pallas"]["tokens_per_sec"]
                 / r["reference"]["tokens_per_sec"])
        rows.append({"P": frac, **r, "pallas_vs_reference": ratio})
        emit(f"kernel_softtrain/P={frac}/reference",
             r["reference"]["sec_per_step"] * 1e6,
             f"tokens_per_sec={r['reference']['tokens_per_sec']:.0f}")
        emit(f"kernel_softtrain/P={frac}/pallas",
             r["pallas"]["sec_per_step"] * 1e6,
             f"tokens_per_sec={r['pallas']['tokens_per_sec']:.0f};"
             f"vs_reference={ratio:.2f}x")
    with open(out_path, "w") as f:
        json.dump({
            "model": {"d_model": cfg.d_model, "d_ff": cfg.d_ff,
                      "num_layers": cfg.num_layers, "heads": cfg.num_heads},
            "batch": B, "seq": S, "steps": steps, "mask_block": 128,
            "backend": jax.default_backend(),
            "interpret": jax.default_backend() == "cpu",
            "compiled_programs": compiled,
            "results": rows,
            "note": ("CPU cells run the Pallas kernels in interpret mode — "
                     "they pin numerics and shape-stable dispatch (one "
                     "compiled step per substrate across all P), not wall "
                     "clock; the block-skip FLOP win needs a TPU host "
                     "(native pallas_call)."),
        }, f, indent=2)
    print(f"wrote {out_path}")


# ---------------------------------------------------------------------------
# TPU-native soft-training: compiled FLOP reduction (cost_analysis)
# ---------------------------------------------------------------------------


def bench_softtrain_flops():
    """compact (gathered) MLP vs full MLP: the compiled FLOPs shrink ~P —
    the paper's straggler acceleration mechanism on the MXU."""
    from repro.models.layers import mlp_fwd, mlp_spec
    from repro.models.module import init_params
    from repro.parallel.hlo_analysis import cost_analysis_dict

    d, ff = 512, 2048
    spec = mlp_spec(d, ff, "silu")
    params = init_params(jax.random.PRNGKey(0), spec)
    x = jnp.ones((64, 128, d))

    full = jax.jit(lambda p, x: mlp_fwd(p, x, "silu")).lower(
        params, x).compile()
    base = cost_analysis_dict(full)["flops"]
    for pfrac in (0.5, 0.25):
        k = int(ff * pfrac)
        idx = jnp.arange(k, dtype=jnp.int32)
        comp = jax.jit(lambda p, x, i: mlp_fwd(p, x, "silu", active_idx=i)
                       ).lower(params, x, idx).compile()
        flops = cost_analysis_dict(comp)["flops"]
        emit(f"softtrain/compact_mlp/P={pfrac}", 0.0,
             f"flop_fraction={flops / base:.3f}")


def table_million_population(populations=(10_000, 100_000, 1_000_000),
                             participation=64, rounds=3,
                             modes=("none", "topk", "quant", "delta"),
                             conv_rounds=12,
                             host_budget_bytes=16 * 1024 ** 3,
                             out_path="BENCH_million_population.json"):
    """Million-client populations under a stated host-memory budget.

    One subprocess per (N, mode) cell (benchmarks/million_worker.py):
    sharded engine, K=64 sampled clients/round, uplink compression at the
    aggregation boundary.  Reported against the STATED budget
    (``host_budget_bytes``, default 16 GiB): peak host RSS over the whole
    worker lifetime (population setup included), uplink bytes/round, and
    rounds/sec.  Warmup round runs outside the timed window (same
    discipline as the async bench).  Every cell asserts shape-stable
    compilation and peak RSS under budget; the topk cells must clear the
    >= 10x uplink reduction the compression layer exists for.

    A small in-process convergence table (full participation, N=8,
    ``conv_rounds`` rounds) records the final metric of every lossy mode
    against ``none`` — the accuracy price of each wire format.
    """
    import json
    import os as _os
    import subprocess
    import sys

    repo = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))

    def cell(n, mode):
        env = dict(_os.environ, PYTHONPATH=_os.path.join(repo, "src"))
        cmd = [sys.executable, "-m", "benchmarks.million_worker",
               "--population", str(n), "--participation",
               str(participation), "--rounds", str(rounds),
               "--mode", mode]
        r = subprocess.run(cmd, env=env, cwd=repo, capture_output=True,
                           text=True, timeout=3600)
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("MILLION ")][-1]
        rec = json.loads(line[len("MILLION "):])
        assert rec["compiled_programs"] == 1, rec   # no recompile per draw
        assert rec["peak_host_bytes"] < host_budget_bytes, rec
        rec["within_budget"] = True
        emit(f"million_population/N={n}/{mode}",
             rec["sec_per_round"] * 1e6,
             f"rounds_per_sec={rec['rounds_per_sec']:.2f};"
             f"peak_gb={rec['peak_host_bytes'] / 1024 ** 3:.2f};"
             f"uplink_mb_per_round="
             f"{rec['uplink_bytes_per_round'] / 1e6:.2f}")
        return rec

    cells = [cell(n, mode) for n in populations for mode in modes]
    by = {(r["population"], r["mode"]): r for r in cells}
    n_max = max(populations)
    reduction = {m: by[(n_max, "none")]["uplink_bytes_per_round"]
                 / by[(n_max, m)]["uplink_bytes_per_round"]
                 for m in modes if m != "none"}
    assert reduction.get("topk", 10.0) >= 10.0, reduction
    emit(f"million_population/N={n_max}/uplink_reduction", 0.0,
         ";".join(f"{m}={x:.1f}x" for m, x in sorted(reduction.items())))

    # convergence delta: the accuracy price of each wire format
    cfg = reduced(CNNS["lenet"])
    imgs, labels = class_gaussian_images(
        800, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0)
    ti, tl = class_gaussian_images(128, cfg.image_size, cfg.in_channels,
                                   cfg.num_classes, seed=9)
    parts = partition_iid(len(labels), 8, seed=0)
    conv = {}
    for mode in modes:
        hcfg = HeliosConfig()
        clients = setup_clients(make_fleet(4, 4), parts, hcfg)
        run = BatchedFLRun(cfg, hcfg, "helios", clients,
                           {"images": imgs, "labels": labels},
                           {"images": ti, "labels": tl},
                           local_steps=1, batch_size=16, lr=0.1, seed=0,
                           eval_batch=128, compression=mode)
        run.run_sync(conv_rounds, eval_every=0)
        conv[mode] = {"final_accuracy": run.evaluate(),
                      "uplink_bytes": run.uplink_bytes()}
    for mode in modes:
        conv[mode]["delta_vs_none"] = (conv[mode]["final_accuracy"]
                                       - conv["none"]["final_accuracy"])
        emit(f"million_population/convergence/{mode}", 0.0,
             f"acc={conv[mode]['final_accuracy']:.4f};"
             f"delta={conv[mode]['delta_vs_none']:+.4f}")

    with open(out_path, "w") as f:
        json.dump({
            "participation": participation, "rounds": rounds,
            "scheme": "helios", "host_budget_bytes": host_budget_bytes,
            "host_cpu_count": _os.cpu_count(),
            "cells": cells,
            "uplink_reduction_at_max_n": reduction,
            "convergence": {"rounds": conv_rounds, "clients": 8,
                            "table": conv},
            "note": ("peak_host_bytes is worker-process ru_maxrss "
                     "(population setup included); uplink bytes follow "
                     "the wire formats in optim/compression.py "
                     "(fp16 values for topk, int codes + per-leaf "
                     "scales for quant/delta); error-feedback rows "
                     "materialize host-side only for clients that have "
                     "participated"),
        }, f, indent=2)
    print(f"wrote {out_path}")


TABLES = {
    "fig5": table_convergence,
    "speedup": table_speedup,
    "fig6": table_aggregation_opt,
    "fig7": table_noniid,
    "ablation": table_ps_ablation,
    "scheme_gauntlet": table_scheme_gauntlet,
    "batched": table_batched_rounds,
    "federated_lm": table_federated_lm,
    "sharded_population": table_sharded_population,
    "million_population": table_million_population,
    "async_events": table_async_events,
    "contracts": table_contracts_overhead,
    "observability": table_observability,
    "serve_traffic": table_serve_traffic,
    "kernel_softtrain": table_kernel_softtrain,
    "kernels": bench_kernels,
    "softtrain": bench_softtrain_flops,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()

    only = args.only.split(",") if args.only else list(TABLES)
    for name in only:
        fn = TABLES[name]
        print(f"## {name}", flush=True)
        if args.quick and name == "fig5":
            fn(models=("lenet",), rounds=6)
        elif args.quick and name in ("speedup", "fig6", "fig7"):
            fn(rounds=6)
        elif args.quick and name == "scheme_gauntlet":
            fn(rounds=3)
        elif args.quick and name == "batched":
            fn(counts=(16, 64), rounds=2)
        elif args.quick and name == "federated_lm":
            fn(counts=(4,), rounds=2, ce_rounds=2)
        elif args.quick and name == "sharded_population":
            fn(devices=(1, 16), populations=(256,), rounds=4)
        elif args.quick and name == "million_population":
            fn(populations=(4096,), participation=32, rounds=2,
               conv_rounds=4)
        elif args.quick and name == "async_events":
            fn(counts=(64,), capable_per_client=0.5)
        elif args.quick and name == "contracts":
            fn(n_clients=4, rounds=3)
        elif args.quick and name == "observability":
            fn(n_clients=4, rounds=3, reps=2)
        elif args.quick and name == "serve_traffic":
            fn(rounds=2, rate_hz=50.0, max_requests=40)
        elif args.quick and name == "kernel_softtrain":
            fn(fracs=(0.25, 1.0), steps=2)
        else:
            fn()
    print(f"\n{len(ROWS)} rows")


if __name__ == "__main__":
    main()
