"""Roofline collation: reads reports/dryrun/*.json into the EXPERIMENTS.md
§Dry-run and §Roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline [--tag single] [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS, SHAPES

ORDER = list(ARCHS)
SHAPE_ORDER = list(SHAPES)


def load(tag: str = "single", directory: str = "reports/dryrun"):
    recs = {}
    for f in glob.glob(os.path.join(directory, f"*_{tag}.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"])] = r
    return recs


def _fmt(x, digits=2):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    return f"{x:.{digits}e}"


def markdown_table(recs, tag: str) -> str:
    lines = [
        f"### Roofline terms — {tag}-pod mesh "
        f"(per device; v5e: 197 TF bf16, 819 GB/s HBM, 50 GB/s ICI)",
        "",
        "| arch | shape | t_compute | t_memory | t_mem(flash) | "
        "t_collective | bottleneck | useful (6ND/HLO) | peak HBM/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | — | "
                             f"SKIP: {r['reason'][:40]} | — | — |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | — | — | — | — | "
                             f"ERROR | — | — |")
                continue
            ro = r["roofline"]
            peak = (r.get("memory") or {}).get("peak_bytes")
            tmf = r.get("t_memory_flash_s", ro["t_memory_s"])
            lines.append(
                f"| {arch} | {shape} | {_fmt(ro['t_compute_s'])}s | "
                f"{_fmt(ro['t_memory_s'])}s | {_fmt(tmf)}s | "
                f"{_fmt(ro['t_collective_s'])}s | "
                f"**{ro['bottleneck']}** | {ro['useful_ratio']:.2f} | "
                f"{(peak or 0) / 1e9:.1f} GB |")
    return "\n".join(lines)


def bottleneck_note(r) -> str:
    """One sentence: what would move this cell's dominant term down."""
    if r["status"] != "ok":
        return ""
    ro = r["roofline"]
    b = ro["bottleneck"]
    attn = r.get("attn_score_bytes", 0)
    coll = r.get("collectives", {})
    if b == "memory":
        if attn > 0.3 * r.get("hlo_bytes", 1):
            return ("S^2 attention-score traffic dominates: the Pallas "
                    "flash kernel (VMEM-resident scores) is the fix — see "
                    "t_mem(flash).")
        if r["shape"].startswith("decode") or r["shape"].startswith("long"):
            return ("decode reads the whole KV/SSM state once per token — "
                    "already near the roofline floor; further wins need a "
                    "quantized (int8) cache or batching more requests.")
        return ("elementwise/stash traffic between fusion boundaries: "
                "bigger fused blocks (TPU backend) or fewer microbatches.")
    if b == "collective":
        if coll.get("all-to-all", 0) > 0.3 * sum(coll.values()):
            return ("MoE dispatch all-to-all: larger moe_groups (local "
                    "dispatch) or expert replication when the pool is small.")
        return ("per-layer TP all-reduces: reduce-scatter+all-gather "
                "sequence parallelism, or shift parallelism from model to "
                "data axis for this size.")
    return ("compute-bound: increase per-device batch or enable the "
            "compact soft-training path (FLOPs scale with P).")


def summary(recs) -> dict:
    out = {"ok": 0, "skipped": 0, "error": 0, "bottlenecks": {}}
    for r in recs.values():
        out[r["status"]] = out.get(r["status"], 0) + 1
        if r["status"] == "ok":
            b = r["roofline"]["bottleneck"]
            out["bottlenecks"][b] = out["bottlenecks"].get(b, 0) + 1
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="single")
    ap.add_argument("--dir", default="reports/dryrun")
    args = ap.parse_args()
    recs = load(args.tag, args.dir)
    print(markdown_table(recs, args.tag))
    print()
    print(summary(recs))


if __name__ == "__main__":
    main()
