"""Subprocess worker for the million_population benchmark.

One invocation = one (population N, compression mode) cell of
``benchmarks/run.py --only million_population``: the sharded engine with
K-client participation over an N-client population, uplink compression
on, peak host RSS measured over the whole process lifetime
(``resource.getrusage``) so population setup counts against the stated
memory budget.

Population construction is deliberately lean: straggler identification /
volume assignment run once over an 8-profile TEMPLATE fleet (the paper's
heterogeneity settings) and the N clients cycle those templates — the
O(N * stragglers) membership scan of ``setup_clients`` would dominate at
N=10^6 without changing what the bench measures.  All clients share ONE
data-index array (the bench axis is population state + uplink volume,
not dataset size).

  python -m benchmarks.million_worker --population 1000000 \
      --participation 64 --rounds 3 --mode topk
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_HOST_DEVICES", "1"))

import argparse
import json
import resource
import time

import jax
import numpy as np

from repro.configs import CNNS, HeliosConfig, reduced
from repro.data.synthetic import class_gaussian_images
from repro.federated import ShardedFLRun, make_fleet, setup_clients
from repro.federated.runtime import Client


def build_population(n: int, data_len: int, hcfg: HeliosConfig):
    """N clients cycling an 8-profile identified template fleet."""
    tmpl = setup_clients(make_fleet(4, 4), [np.arange(8)] * 8, hcfg)
    idx = np.arange(data_len)
    return [Client(cid=i, profile=tmpl[i % 8].profile, data_idx=idx,
                   volume=tmpl[i % 8].volume,
                   is_straggler=tmpl[i % 8].is_straggler)
            for i in range(n)]


def peak_rss_bytes() -> int:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lenet")
    ap.add_argument("--population", type=int, default=4096)
    ap.add_argument("--participation", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--mode", default="none",
                    choices=("none", "topk", "quant", "delta"))
    ap.add_argument("--frac", type=float, default=0.05)
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch-size", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(CNNS[args.model])
    imgs, labels = class_gaussian_images(
        4096, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0)
    ti, tl = class_gaussian_images(
        256, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=99)
    hcfg = HeliosConfig()
    t0 = time.perf_counter()
    clients = build_population(args.population, len(labels), hcfg)
    run = ShardedFLRun(cfg, hcfg, "helios", clients,
                       {"images": imgs, "labels": labels},
                       {"images": ti, "labels": tl},
                       local_steps=args.local_steps,
                       batch_size=args.batch_size, lr=0.05, seed=0,
                       participation=args.participation,
                       compression=args.mode, comp_frac=args.frac,
                       comp_bits=args.bits)
    setup_s = time.perf_counter() - t0

    run.run_sync(1, eval_every=0)                 # compile warmup
    jax.block_until_ready(run.global_params)
    t0 = time.perf_counter()
    run.run_sync(args.rounds, eval_every=0)
    jax.block_until_ready(run.global_params)
    dt = time.perf_counter() - t0

    total_rounds = args.rounds + 1                # warmup included in bytes
    rec = {
        "model": args.model, "population": args.population,
        "participation": args.participation, "mode": args.mode,
        "frac": args.frac, "bits": args.bits, "rounds": args.rounds,
        "rounds_per_sec": args.rounds / dt,
        "sec_per_round": dt / args.rounds,
        "setup_s": setup_s,
        "peak_host_bytes": peak_rss_bytes(),
        "pop_state_bytes": sum(
            x.nbytes for x in jax.tree.leaves(run._pop_state)),
        "error_store_bytes": (run._err_store.nbytes()
                              if args.mode != "none" else 0),
        "error_rows_touched": (run._err_store.touched()
                               if args.mode != "none" else 0),
        "uplink_bytes_total": run.uplink_bytes(),
        "uplink_bytes_per_round": run.uplink_bytes() / total_rounds,
        "uplink_updates": run.uplink_updates,
        # 1 == no recompile across sampled cohorts after warmup
        "compiled_programs": run._round_fn._cache_size(),
    }
    print("MILLION " + json.dumps(rec), flush=True)


if __name__ == "__main__":
    main()
