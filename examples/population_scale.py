"""Population-scale federated rounds: partial participation + client
sharding.

A persistent population of N clients (half Table-I stragglers) keeps its
Helios soft-training state server-side while only a sampled cohort of K
trains each round — the regime real FL servers run in.  The round executes
as ONE shape-stable shard_map program over a ``("clients",)`` device mesh,
so the same script scales from this process's single device to a forced
multi-device host:

  PYTHONPATH=src python examples/population_scale.py \
      --population 1024 --participation 32 --rounds 10

  # 16-way client sharding (must be set before jax initializes -> env var):
  PYTHONPATH=src REPRO_HOST_DEVICES=16 python examples/population_scale.py \
      --population 4096 --participation 32 --sampler time_weighted
"""
import os

if os.environ.get("REPRO_HOST_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_HOST_DEVICES"])

import argparse
import time

import jax

from repro.configs import CNNS, HeliosConfig, reduced
from repro.data.federated import partition_iid_lazy
from repro.data.synthetic import class_gaussian_images
from repro.federated import ShardedFLRun, make_fleet, setup_clients


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lenet",
                    choices=["lenet", "alexnet", "resnet18"])
    ap.add_argument("--population", type=int, default=1024)
    ap.add_argument("--participation", type=int, default=32)
    ap.add_argument("--sampler", default="uniform",
                    choices=["uniform", "time_weighted"])
    ap.add_argument("--rounds", type=int, default=10)
    args = ap.parse_args()

    cfg = reduced(CNNS[args.model])
    imgs, labels = class_gaussian_images(
        8192, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0)
    ti, tl = class_gaussian_images(
        512, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=99)
    n, k = args.population, args.participation
    hcfg = HeliosConfig()
    # lazy partition: one shared permutation, no N per-client index arrays
    parts = partition_iid_lazy(len(labels), n, seed=0)
    clients = setup_clients(make_fleet(n - n // 2, n // 2), parts, hcfg)
    run = ShardedFLRun(cfg, hcfg, "helios", clients,
                       {"images": imgs, "labels": labels},
                       {"images": ti, "labels": tl},
                       local_steps=1, batch_size=16, lr=0.05,
                       participation=k, sampler=args.sampler)
    print(f"== {args.model}: N={n} clients, K={k}/round "
          f"({args.sampler}), {run._mesh.devices.size} mesh shard(s), "
          f"cohort padded to {run._kpad} ==")

    run.run_sync(1, eval_every=0)              # untimed compile warmup
    jax.block_until_ready(run.global_params)
    t0 = time.perf_counter()
    run.run_sync(args.rounds, eval_every=0)
    jax.block_until_ready(run.global_params)
    wall = time.perf_counter() - t0
    sampled = {i for cohort in run.cohort_log for i in cohort}
    print(f"{args.rounds} rounds in {wall:.1f}s "
          f"({args.rounds / wall:.2f} rounds/s) | acc {run.evaluate():.3f}")
    print(f"clients touched: {len(sampled)}/{n} | compiled round "
          f"programs: {run._round_fn._cache_size()} (shape-stable)")
    vols = sorted(c.volume for c in run.clients if c.is_straggler
                  and c.volume < 1.0)[:8]
    print(f"adapted straggler volumes (sampled cohorts only): "
          f"{[round(v, 2) for v in vols]}")


if __name__ == "__main__":
    main()
