"""Serve inference traffic against the live global model while it trains.

The serving story end to end, in one process:

* a ``BatchedFLRun`` trains a reduced dense-transformer LM on Non-IID
  Markov-topic token streams and PUBLISHES the global params every round
  (``publish_dir`` -> atomic ``checkpoint.save``: tmp write + fsync +
  ``os.replace``, so a reader can never observe a partial snapshot);
* a ``ServeLoop`` on the main thread serves batched greedy generation
  (``GenerationServer``: jitted prefill/decode with the params as a
  TRACED argument — hot-swapping never recompiles) and polls the publish
  directory between requests behind an eval-gated promotion rule:
  a candidate snapshot is promoted only if its held-out CE does not
  regress beyond ``--tol`` against the currently-served snapshot;
* a deterministic open-loop Poisson load generator fixes the arrival
  schedule by seed; per-request latency is completion minus SCHEDULED
  arrival, so queueing under overload is priced in.

The request path takes zero locks: a swap is one GIL-atomic rebind of an
immutable snapshot reference between jitted calls.  Both planes share
one armed recorder, so the run log shows training rounds AND the serving
plane (swaps, promotion decisions, request latency, staleness):

  PYTHONPATH=src python examples/serve_while_train.py --rounds 4
  PYTHONPATH=src python -m repro.obs report serve_demo
"""
import argparse
import tempfile

import jax
import numpy as np

from repro import checkpoint as CKPT
from repro.configs import ARCHS, HeliosConfig, reduced
from repro.data.federated import partition_by_topic
from repro.data.synthetic import markov_tokens, markov_topic_tokens
from repro.federated import BatchedFLRun, make_fleet, setup_clients
from repro.launch.serve import (GenerationServer, PoissonTraffic, ServeLoop,
                                make_ce_eval, serve_batch,
                                serve_while_training)
from repro.models import init_params
from repro.obs import Recorder


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--rate-hz", type=float, default=20.0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=4)
    ap.add_argument("--tol", type=float, default=0.05,
                    help="promotion tolerance on held-out CE")
    ap.add_argument("--kernels", default="reference",
                    choices=("reference", "pallas"))
    ap.add_argument("--out", default="serve_demo",
                    help="run-log directory for `repro.obs report`")
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    data_vocab = min(64, cfg.vocab_size)
    tokens, topics = markov_topic_tokens(256, 32, data_vocab,
                                         n_topics=8, seed=0)
    test_tokens, _ = markov_topic_tokens(64, 32, data_vocab,
                                         n_topics=8, seed=99)
    n = args.clients
    hcfg = HeliosConfig()
    parts = partition_by_topic(topics, n, topics_per_client=2)
    clients = setup_clients(make_fleet(n - n // 2, n // 2), parts, hcfg)

    rec = Recorder(armed=True)
    pub = tempfile.mkdtemp(prefix="serve_pub_")
    run = BatchedFLRun(cfg, hcfg, "helios", clients, {"tokens": tokens},
                       {"tokens": test_tokens}, local_steps=2,
                       batch_size=8, lr=0.1, seed=0, eval_batch=64,
                       recorder=rec, publish_dir=pub, publish_every=1)

    srv = GenerationServer(cfg, args.batch, args.prompt_len, gen=args.gen,
                           kernels=args.kernels)
    held = {"tokens": jax.numpy.asarray(test_tokens[:32])}
    serve = ServeLoop(pub, init_params(jax.random.PRNGKey(0), cfg),
                      request_fn=srv, eval_fn=make_ce_eval(cfg, held),
                      higher_is_better=False, tol=args.tol, recorder=rec)
    # publish the round-0 model so traffic has something to serve from
    # the first request on
    CKPT.save(pub, 0, run.global_params, keep=run.publish_keep,
              metadata={"round": 0, "sim_time": 0.0, "scheme": run.scheme})
    serve.poll()

    prompts = markov_tokens(args.batch, args.prompt_len, cfg.padded_vocab,
                            seed=7)
    req = serve_batch(cfg, prompts, np.random.default_rng(7))
    serve.handle(req)                                  # compile warmup
    stats = serve_while_training(
        lambda: run.run_sync(args.rounds), serve,
        PoissonTraffic(rate_hz=args.rate_hz, seed=0), lambda i: req,
        min_requests=10)

    lat = sorted(stats["latency_ms"])
    m = len(lat)
    print(f"served {stats['requests']} requests at "
          f"{stats['requests_per_sec']:.1f} req/s "
          f"(offered {args.rate_hz:g} Hz): "
          f"p50={lat[m // 2]:.1f}ms "
          f"p99={lat[min((99 * m) // 100, m - 1)]:.1f}ms")
    print(f"swaps={rec.count('serve_swaps')} "
          f"promotions={rec.count('serve_promotions')} "
          f"rejections={rec.count('serve_rejections')} "
          f"published={rec.count('published_snapshots')}; "
          f"now serving round {serve.served_round} "
          f"(ce={serve.served_metric:.3f})")
    print(f"compiled programs across all swaps: {srv.programs()}")
    rec.flush(args.out)
    print(f"run log -> {args.out} "
          f"(PYTHONPATH=src python -m repro.obs report {args.out})")


if __name__ == "__main__":
    main()
