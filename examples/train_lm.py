"""End-to-end LM training driver: the FULL xlstm-125m (~100M params) on a
synthetic Markov token stream, with Helios soft-training enabled.

A few hundred steps on CPU take a while (~6.5e10 FLOPs/step at the default
batch); pass --steps 25 for a smoke run.  The loss must drop well below the
uniform baseline ln(50304) ~ 10.8 toward the Markov entropy ln(8) ~ 2.1.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--volume", type=float, default=0.75)
    ap.add_argument("--ckpt-dir", default="/tmp/helios_lm")
    args = ap.parse_args()

    losses = train_main([
        "--arch", "xlstm-125m",               # full 103M-param config
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--lr", "1e-3",
        "--volume", str(args.volume),
        "--ckpt-dir", args.ckpt_dir,
        "--log-every", "5",
    ])
    assert losses[-1] < losses[0], "loss must improve"


if __name__ == "__main__":
    main()
