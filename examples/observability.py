"""Produce and read one telemetry run log (the repro.obs layer).

Every engine already does its accounting through a ``repro.obs.Recorder``
(counters, byte gauges, device-scalar accumulators) — that layer is free
and always on.  Arming telemetry (``REPRO_OBS=on``, or in-process as
below) additionally streams dual-clock events: every span/round/bucket
event carries the engine's SIMULATED clock (deterministic — fixed-seed
streams are identical across engines) next to the host WALL clock (what
the instrumented sections really cost).  ``flush()`` writes the JSONL
event log + run manifest the ``repro.obs`` CLI consumes:

  PYTHONPATH=src python examples/observability.py --out obs_demo

  # the same report this script prints, straight from the CLI:
  PYTHONPATH=src python -m repro.obs report obs_demo

  # regression-gate one run log against another (nonzero on regression):
  PYTHONPATH=src python -m repro.obs diff obs_demo other_run

Capture a ``jax.profiler`` trace around one chosen round with
``REPRO_OBS_PROFILE=<round>`` (or ``profile_round=`` on the Recorder).
"""
import argparse

from repro.configs import CNNS, HeliosConfig, reduced
from repro.data.federated import partition_noniid
from repro.data.synthetic import class_gaussian_images
from repro.federated import BatchedFLRun, make_fleet, setup_clients
from repro.obs import Recorder, load_events, render, summarize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lenet",
                    choices=["lenet", "alexnet", "resnet18"])
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--out", default="obs_demo",
                    help="run-log directory (events.jsonl + manifest.json)")
    ap.add_argument("--profile-round", type=int, default=None,
                    help="capture a jax.profiler trace around this round")
    args = ap.parse_args()

    cfg = reduced(CNNS[args.model])
    imgs, labels = class_gaussian_images(
        1024, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0)
    ti, tl = class_gaussian_images(
        128, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=99)
    n = args.clients
    hcfg = HeliosConfig()
    parts = partition_noniid(labels, n, shards_per_client=4, seed=0)
    clients = setup_clients(make_fleet(n - n // 2, n // 2), parts, hcfg)

    # an explicitly-armed recorder overrides REPRO_OBS for this run only
    rec = Recorder(armed=True, profile_round=args.profile_round)
    run = BatchedFLRun(cfg, hcfg, "helios", clients,
                       {"images": imgs, "labels": labels},
                       {"images": ti, "labels": tl},
                       local_steps=1, batch_size=16, lr=0.05, seed=0,
                       recorder=rec)
    run.run_sync(args.rounds)

    out = rec.flush(args.out)
    print(f"== run log: {out['events']} ==\n")
    events = load_events(args.out)
    print(render(events))
    summ = summarize(events)
    print(f"\n== summary: {summ['rounds']} rounds, "
          f"final {summ.get('metric_name')}={summ.get('final_metric'):.3f}, "
          f"uplink {summ['uplink_mb']:.2f} MB / "
          f"downlink {summ['downlink_mb']:.2f} MB ==")
    print("rerun with --profile-round 1 (or REPRO_OBS_PROFILE=1) to drop "
          "a jax.profiler trace next to the log")


if __name__ == "__main__":
    main()
