"""Elastic collaboration (§VI.C): devices join and leave mid-training.

A new straggler joining is identified (white-box profile), assigned a
soft-training volume, and admitted without interrupting the collaboration;
a leaving device just drops out of the next aggregation.

  PYTHONPATH=src python examples/elastic_scaling.py
"""

from repro.configs import CNNS, HeliosConfig, reduced
from repro.data.federated import partition_noniid
from repro.data.synthetic import class_gaussian_images
from repro.federated import FLRun, TABLE_I, make_fleet, setup_clients

cfg = reduced(CNNS["lenet"])
imgs, labels = class_gaussian_images(2000, cfg.image_size, cfg.in_channels,
                                     cfg.num_classes, seed=0)
ti, tl = class_gaussian_images(512, cfg.image_size, cfg.in_channels,
                               cfg.num_classes, seed=99)
parts = partition_noniid(labels, 6, shards_per_client=4)
hcfg = HeliosConfig()

clients = setup_clients(make_fleet(2, 2), parts[:4], hcfg)
run = FLRun(cfg, hcfg, "helios", clients,
            {"images": imgs, "labels": labels},
            {"images": ti, "labels": tl},
            local_steps=5, lr=0.1)

print("phase 1: 2 capable + 2 stragglers")
run.run_sync(4)
print(f"  acc={run.history[-1]['acc']:.3f}")

print("phase 2: a DeepLens straggler JOINS (white-box identification)")
new = run.add_client(TABLE_I[3], parts[4])
print(f"  identified straggler={new.is_straggler}, assigned P={new.volume:.2f}")
run.run_sync(4)
print(f"  acc={run.history[-1]['acc']:.3f} with {len(run.clients)} devices")

print("phase 3: the newcomer LEAVES")
run.remove_client(new.cid)
run.run_sync(2)
print(f"  acc={run.history[-1]['acc']:.3f} with {len(run.clients)} devices")
print("elastic join/leave complete — no restart, no lost state.")
