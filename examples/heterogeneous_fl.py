"""Paper reproduction driver (Figs. 5-7 at CPU scale): 4- or 6-device
federated collaboration with Table-I stragglers, comparing Helios against
Syn FL / Asyn FL / Random [12] / AFO [6] on accuracy AND simulated wall time.

  PYTHONPATH=src python examples/heterogeneous_fl.py --devices 4 --rounds 10
"""
import argparse

import numpy as np

from repro.configs import CNNS, HeliosConfig, reduced
from repro.data.federated import partition_noniid
from repro.data.synthetic import class_gaussian_images
from repro.federated import FLRun, make_fleet, setup_clients


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lenet",
                    choices=["lenet", "alexnet", "resnet18"])
    ap.add_argument("--devices", type=int, default=4, choices=[4, 6])
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--noniid", action="store_true", default=True)
    args = ap.parse_args()

    nc = ns = args.devices // 2
    cfg = reduced(CNNS[args.model])
    imgs, labels = class_gaussian_images(
        2000, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0)
    ti, tl = class_gaussian_images(
        512, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=99)
    parts = partition_noniid(labels, args.devices, shards_per_client=4)
    hcfg = HeliosConfig()

    print(f"== {args.model}, {nc} capable + {ns} stragglers, "
          f"Non-IID={args.noniid} ==")
    results = {}
    for scheme in ("syn", "asyn", "random", "afo", "helios"):
        clients = setup_clients(make_fleet(nc, ns), parts, hcfg)
        run = FLRun(cfg, hcfg, scheme, clients, imgs, labels, ti, tl,
                    local_steps=5, lr=0.1)
        if scheme in ("syn", "helios", "random"):
            hist = run.run_sync(args.rounds)
        else:
            hist = run.run_async(args.rounds)
        results[scheme] = hist
        print(f"{scheme:7s} | final acc {hist[-1]['acc']:.3f} | "
              f"sim time {hist[-1]['time']:7.1f} | "
              f"time/cycle {hist[-1]['time'] / max(1, hist[-1]['cycle']):.2f}")

    t_syn = results["syn"][-1]["time"] / max(1, results["syn"][-1]["cycle"])
    t_hel = results["helios"][-1]["time"] / max(
        1, results["helios"][-1]["cycle"])
    print(f"\nHelios cycle speedup vs Syn FL: {t_syn / t_hel:.2f}x "
          f"(paper: up to 2.5x)")
    if ns >= 2:
        vols = results["helios"][-1].get("volumes", [])
        print(f"adapted straggler volumes: "
              f"{[round(v, 2) for v in vols if v < 1.0]}")


if __name__ == "__main__":
    main()
