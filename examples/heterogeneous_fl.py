"""Paper reproduction driver (Figs. 5-7 at CPU scale): 4- or 6-device
federated collaboration with Table-I stragglers, comparing Helios against
Syn FL / Asyn FL / Random [12] / AFO [6] on accuracy AND simulated wall time.

  PYTHONPATH=src python examples/heterogeneous_fl.py --devices 4 --rounds 10

Population-scale mode: ``--clients N`` (e.g. 64-256) simulates a large
half-straggler fleet; pair it with ``--engine batched`` to run every round
as one jitted vmapped program instead of a per-client Python loop:

  PYTHONPATH=src python examples/heterogeneous_fl.py --clients 128 \
      --engine batched --rounds 5

With ``--engine batched`` the async schemes (asyn / afo) also leave the
sequential event loop: BatchedFLRun inherits the bucketed event engine
(equal-time completions execute as one vmapped program — see
examples/async_events.py for the dedicated walkthrough).
"""
import argparse
import time

import jax

from repro.configs import CNNS, HeliosConfig, reduced
from repro.data.federated import partition_iid, partition_noniid
from repro.data.synthetic import class_gaussian_images
from repro.federated import BatchedFLRun, FLRun, make_fleet, setup_clients


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lenet",
                    choices=["lenet", "alexnet", "resnet18"])
    ap.add_argument("--devices", type=int, default=4, choices=[4, 6])
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--noniid", action="store_true", default=True)
    ap.add_argument("--engine", default="sequential",
                    choices=["sequential", "batched"])
    ap.add_argument("--clients", type=int, default=0,
                    help="population-scale mode: total client count "
                         "(half stragglers); 0 = paper's 4/6-device setting")
    args = ap.parse_args()

    runner = BatchedFLRun if args.engine == "batched" else FLRun
    cfg = reduced(CNNS[args.model])
    imgs, labels = class_gaussian_images(
        2000, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0)
    ti, tl = class_gaussian_images(
        512, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=99)
    hcfg = HeliosConfig()

    if args.clients:
        n = args.clients
        nc, ns = n - n // 2, n // 2
        parts = partition_iid(len(labels), n)
        print(f"== {args.model}, {nc} capable + {ns} stragglers, "
              f"engine={args.engine} ==")
        for scheme in ("syn", "helios"):
            clients = setup_clients(make_fleet(nc, ns), parts, hcfg)
            run = runner(cfg, hcfg, scheme, clients,
                         {"images": imgs, "labels": labels},
                         {"images": ti, "labels": tl},
                         local_steps=1, batch_size=16, lr=0.05)
            run.run_sync(1, eval_every=0)      # untimed compile warmup
            jax.block_until_ready(run.global_params)
            t0 = time.perf_counter()
            run.run_sync(args.rounds, eval_every=0)
            jax.block_until_ready(run.global_params)
            wall = time.perf_counter() - t0
            print(f"{scheme:7s} | final acc {run.evaluate():.3f} | "
                  f"wall {wall:6.1f}s ({args.rounds / wall:.2f} rounds/s)")
        return

    nc = ns = args.devices // 2
    parts = partition_noniid(labels, args.devices, shards_per_client=4)

    print(f"== {args.model}, {nc} capable + {ns} stragglers, "
          f"Non-IID={args.noniid}, engine={args.engine} ==")
    results = {}
    for scheme in ("syn", "asyn", "random", "afo", "helios"):
        clients = setup_clients(make_fleet(nc, ns), parts, hcfg)
        run = runner(cfg, hcfg, scheme, clients,
                     {"images": imgs, "labels": labels},
                     {"images": ti, "labels": tl},
                     local_steps=5, lr=0.1)
        if scheme in ("syn", "helios", "random"):
            hist = run.run_sync(args.rounds)
        else:
            hist = run.run_async(args.rounds)
        results[scheme] = hist
        print(f"{scheme:7s} | final acc {hist[-1]['acc']:.3f} | "
              f"sim time {hist[-1]['time']:7.1f} | "
              f"time/cycle {hist[-1]['time'] / max(1, hist[-1]['cycle']):.2f}")

    t_syn = results["syn"][-1]["time"] / max(1, results["syn"][-1]["cycle"])
    t_hel = results["helios"][-1]["time"] / max(
        1, results["helios"][-1]["cycle"])
    print(f"\nHelios cycle speedup vs Syn FL: {t_syn / t_hel:.2f}x "
          f"(paper: up to 2.5x)")
    if ns >= 2:
        vols = results["helios"][-1].get("volumes", [])
        print(f"adapted straggler volumes: "
              f"{[round(v, 2) for v in vols if v < 1.0]}")


if __name__ == "__main__":
    main()
