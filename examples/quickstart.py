"""Quickstart: Helios soft-training on one straggler, end to end.

Shows the public API surface: config registry -> model -> Helios state
machine (identify -> volume -> select -> train -> rotate) in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CNNS, HeliosConfig, reduced
from repro.core import soft_train as ST
from repro.core.volume import volume_from_profile
from repro.data.synthetic import class_gaussian_images
from repro.federated.adapter import make_adapter
from repro.federated.heterogeneity import CAPABLE, TABLE_I, cycle_time
from repro.models import build, init_params
from repro.optim import apply_updates, make_optimizer

# 1. a model (the paper's LeNet testbed, reduced for CPU) + its FL adapter
cfg = reduced(CNNS["lenet"])
api = build(cfg)
adapter = make_adapter(cfg)
params = init_params(jax.random.PRNGKey(0), cfg)

# 2. identify the straggler and its optimization target (§IV)
straggler = TABLE_I[0]                       # Jetson Nano (CPU) from Table I
pace = cycle_time(CAPABLE)                   # the collaboration pace
volume = volume_from_profile(cycle_time(straggler), pace)
print(f"straggler={straggler.name} -> soft-training volume P={volume:.2f}")

# 3. soft-training cycles (§V): select -> train -> score -> rotate
hcfg = HeliosConfig(p_s=0.1)
state = ST.init_state(api.mask_schema, volume=volume, seed=0)
imgs, labels = class_gaussian_images(512, cfg.image_size, cfg.in_channels,
                                     cfg.num_classes)
opt = make_optimizer("momentum", 0.1)
opt_state = opt.init(params)


@jax.jit
def train_step(params, opt_state, masks, bi, bl):
    loss, grads = jax.value_and_grad(
        lambda p: api.loss_fn(p, {"images": bi, "labels": bl}, cfg, None,
                              masks))(params)
    updates, opt_state = opt.update(grads, opt_state, params, 0)
    return apply_updates(params, updates), opt_state, loss


rng = np.random.default_rng(0)
for cycle in range(5):
    state = ST.begin_cycle(state, hcfg)                  # Eq. 2 selection
    frac = float(np.mean([float(m.mean()) for m in state["masks"].values()]))
    prev = params
    for _ in range(5):
        idx = rng.integers(0, len(labels), 32)
        params, opt_state, loss = train_step(
            params, opt_state, state["masks"],
            jnp.asarray(imgs[idx]), jnp.asarray(labels[idx]))
    scores = adapter.cycle_scores(params, prev)          # Eq. 1
    state = ST.end_cycle(state, scores, hcfg)            # C_s rotation
    print(f"cycle {cycle}: loss={float(loss):.3f} "
          f"selected={frac:.2f} (target P={volume:.2f})")

print("done — every unit rotates through training while the straggler "
      "computes only a fraction of the model per cycle.")
