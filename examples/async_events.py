"""Event-driven async federated learning at population scale.

Real fleets don't run in rounds: every client pulls the current global
model, trains at its own pace, and its update arrives whenever it arrives
(the asyn / afo schemes, paper §VII.A).  The sequential reference
(``FLRun.run_async``) replays that event-by-event — one jitted dispatch +
one Python-dict snapshot per completion, which caps the population the
simulator can reach.  ``AsyncFLRun`` keeps the event semantics bit-exact
but pops *buckets* of equal-time completions and executes each bucket as
one jitted vmapped program against a device-side snapshot ring:

  PYTHONPATH=src python examples/async_events.py --clients 64 --capable 64

  # jittered arrivals + 10% update loss (still engine-deterministic):
  PYTHONPATH=src python examples/async_events.py --clients 128 \
      --jitter 0.2 --dropout 0.1
"""
import argparse
import time
from collections import Counter

import jax

from repro.configs import CNNS, HeliosConfig, reduced
from repro.data.federated import partition_noniid_lazy
from repro.data.synthetic import class_gaussian_images
from repro.federated import (AsyncFLRun, BernoulliDropout, FLRun,
                             JitteredArrival, make_fleet, setup_clients)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lenet",
                    choices=["lenet", "alexnet", "resnet18"])
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--capable", type=int, default=0,
                    help="capable-client completions to simulate "
                         "(default: one per capable client)")
    ap.add_argument("--scheme", default="afo", choices=["asyn", "afo"])
    ap.add_argument("--jitter", type=float, default=0.0,
                    help="lognormal sigma on completion delays")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-event probability the update is lost")
    args = ap.parse_args()

    cfg = reduced(CNNS[args.model])
    imgs, labels = class_gaussian_images(
        4096, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=0)
    ti, tl = class_gaussian_images(
        256, cfg.image_size, cfg.in_channels, cfg.num_classes, seed=99)
    n = args.clients
    capable = args.capable or n - n // 2
    hcfg = HeliosConfig()
    # lazy non-IID deal: one label ordering + one shard assignment, no
    # N per-client index arrays
    parts = partition_noniid_lazy(labels, n, shards_per_client=4, seed=0)
    kw = dict(local_steps=1, batch_size=16, lr=0.05, seed=0)
    if args.jitter:
        kw["arrival"] = JitteredArrival(sigma=args.jitter)
    if args.dropout:
        kw["dropout"] = BernoulliDropout(p=args.dropout)

    print(f"== {args.model}: N={n} clients (half Table-I stragglers), "
          f"scheme={args.scheme}, {capable} capable completions ==")
    rates = {}
    for name, cls in (("sequential", FLRun), ("bucketed", AsyncFLRun)):
        clients = setup_clients(make_fleet(n - n // 2, n // 2), parts, hcfg)
        run = cls(cfg, hcfg, args.scheme, clients,
                  {"images": imgs, "labels": labels},
                  {"images": ti, "labels": tl}, **kw)
        # warmup over the same budget: the event schedule is deterministic,
        # so this compiles every bucket shape the timed window will see
        run.run_async(capable, eval_every=0)
        jax.block_until_ready(run.global_params)
        t0 = time.perf_counter()
        run.run_async(capable, eval_every=0)
        jax.block_until_ready(run.global_params)
        wall = time.perf_counter() - t0
        rates[name] = run.events_processed / wall
        line = (f"{name:10s} | {run.events_processed} events "
                f"({run.events_dropped} dropped) in {wall:5.1f}s "
                f"= {rates[name]:7.1f} events/s | acc {run.evaluate():.3f}")
        if name == "bucketed":
            sizes = Counter(run.bucket_sizes)
            hist = ", ".join(f"{s}x{c}" for s, c in sorted(sizes.items()))
            line += (f"\n{'':10s} | bucket sizes {{{hist}}} | compiled "
                     f"programs {run.bucket_programs()} | snapshot ring "
                     f"peak {run.snapshot_peak} live anchors")
        print(line)
    print(f"bucketed speedup vs sequential event loop: "
          f"{rates['bucketed'] / rates['sequential']:.2f}x")


if __name__ == "__main__":
    main()
