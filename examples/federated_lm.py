"""Federated language-model training through the family-adapter seam.

The same round engines that reproduce the paper's CNN testbed federate a
small dense transformer on Non-IID token streams: clients hold documents
from a few Markov "topics" (data.synthetic.markov_topic_tokens +
data.federated.partition_by_topic), stragglers soft-train rotating
sub-models, and the server tracks test cross-entropy instead of accuracy.
This is the FLuID / FedEL scenario — sub-model training of transformer-style
models on heterogeneous language clients — expressed with zero family
branches inside the engines.

  PYTHONPATH=src python examples/federated_lm.py --rounds 6
  PYTHONPATH=src python examples/federated_lm.py --engine batched --clients 16
"""
import argparse

import numpy as np

from repro.configs import ARCHS, HeliosConfig, reduced
from repro.data.federated import label_distribution, partition_by_topic
from repro.data.synthetic import markov_topic_tokens
from repro.federated import BatchedFLRun, FLRun, make_fleet, setup_clients


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b",
                    help="any token-stream family (dense/moe/ssm/hybrid); "
                         "reduced() for CPU")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--engine", default="sequential",
                    choices=["sequential", "batched"])
    ap.add_argument("--topics", type=int, default=8)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--data-vocab", type=int, default=64,
                    help="token stream vocab (<= model vocab); small keeps "
                         "CE moving within a few CPU rounds")
    args = ap.parse_args()

    runner = BatchedFLRun if args.engine == "batched" else FLRun
    cfg = reduced(ARCHS[args.arch])
    hcfg = HeliosConfig()

    dv = min(args.data_vocab, cfg.vocab_size)
    tokens, topics = markov_topic_tokens(96 * args.clients, args.seq, dv,
                                         n_topics=args.topics, seed=0)
    test_tokens, _ = markov_topic_tokens(192, args.seq, dv,
                                         n_topics=args.topics, seed=99)
    parts = partition_by_topic(topics, args.clients, topics_per_client=2)
    hist = label_distribution(topics, parts, args.topics)
    cover = (hist > 0).sum(axis=1)
    print(f"== {args.arch} ({cfg.family}), {args.clients} clients, "
          f"{args.topics} topics (each client covers "
          f"{cover.min()}-{cover.max()}), engine={args.engine} ==")
    print(f"model-uniform CE = ln({cfg.vocab_size}) = "
          f"{np.log(cfg.vocab_size):.2f}; stream-uniform = ln({dv}) = "
          f"{np.log(dv):.2f}; Markov floor ~= ln(8) = 2.08")

    nc = args.clients - args.clients // 2
    for scheme in ("syn", "st_only", "helios"):
        clients = setup_clients(make_fleet(nc, args.clients // 2), parts,
                                hcfg)
        run = runner(cfg, hcfg, scheme, clients, {"tokens": tokens},
                     {"tokens": test_tokens}, local_steps=4, batch_size=8,
                     lr=0.5, seed=0, eval_batch=64)
        hist = run.run_sync(args.rounds)
        traj = " -> ".join(f"{h['ce']:.2f}" for h in hist)
        print(f"{scheme:7s} | CE {traj} | sim time {hist[-1]['time']:6.1f} "
              f"| time/cycle {hist[-1]['time'] / hist[-1]['cycle']:.2f}")

    print("\nstragglers soft-train sub-models; Helios's Eq. 10 aggregation "
          "weighs them by selected fraction — same engines, new family.")


if __name__ == "__main__":
    main()
